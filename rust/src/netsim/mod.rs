//! Network cost model — the substitution for the paper's 16-GPU
//! 100Gbps-InfiniBand / throttled-10Gbps testbed (DESIGN.md §1).
//!
//! Wall-clock claims in the paper (Fig 4c/5c, 6, 7c, 8c) decompose into
//! per-step compute time (which we *measure*) plus per-synchronization
//! communication time (which we *model*).  The model is the standard
//! α–β (latency–bandwidth) formulation, priced **per collective
//! algorithm** ([`crate::collective::Algo`]):
//!
//! * **ring** allreduce of `B` bytes over `n` nodes
//!   (Patarasuk & Yuan, the paper's [15]) — the reduction is pipelined,
//!   every link carries `2·(n−1)/n·B`:
//!   `t = 2(n−1)·α + 2·(n−1)/n · B / bw`
//! * **flat** allreduce — gather + broadcast serialized at the leader,
//!   whose link (the bottleneck) carries `2·(n−1)·B`:
//!   `t = 2(n−1)·α + 2·(n−1) · B / bw`  (no 1/n pipelining factor)
//! * allgather (QSGD's compressed-gradient exchange; quantized grads
//!   cannot ride a summing allreduce — paper §VI):
//!   `t = (n−1)·α + (n−1)·B_q / bw`
//! * scalar allreduce (the S_k exchange of Algorithm 2 — "a single
//!   floating-point value"): `t = 2(n−1)·α + 2(n−1)/n · 4 / bw`
//!
//! A [`CommLedger`] accumulates modeled time + **bottleneck-link** bytes
//! per category — so the same ledger re-prices under any bandwidth
//! preset (`modeled_secs` = per-call latency + wire bytes / bw), and
//! `modeled_total_secs` reflects the collective algorithm the run was
//! configured with.  The ledger's algorithm comes from
//! `cfg.sync.collective` via [`CommLedger::with_algo`].

pub mod cluster;

use crate::collective::Algo;
use crate::config::NetConfig;

/// One link/timing model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    /// effective per-node bandwidth, bytes/second
    pub bw: f64,
    /// per-message latency, seconds
    pub alpha: f64,
}

impl NetModel {
    pub fn new(cfg: &NetConfig) -> Self {
        NetModel { bw: cfg.bandwidth_gbps * 1e9 / 8.0, alpha: cfg.latency_us * 1e-6 }
    }

    pub fn infiniband_100g() -> Self {
        Self::new(&NetConfig::infiniband_100g())
    }

    pub fn ethernet_10g() -> Self {
        Self::new(&NetConfig::ethernet_10g())
    }

    /// Ring allreduce of `bytes` over `n` nodes.
    pub fn allreduce_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let nf = n as f64;
        2.0 * (nf - 1.0) * self.alpha + 2.0 * (nf - 1.0) / nf * bytes as f64 / self.bw
    }

    /// Allreduce time under a specific collective algorithm: `Ring` is
    /// pipelined ([`Self::allreduce_time`]); `Flat` serializes the full
    /// gather+broadcast on the leader's link.
    pub fn allreduce_time_with(&self, algo: Algo, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        match algo {
            Algo::Ring => self.allreduce_time(n, bytes),
            Algo::Flat => {
                let nf = n as f64;
                2.0 * (nf - 1.0) * self.alpha + 2.0 * (nf - 1.0) * bytes as f64 / self.bw
            }
        }
    }

    /// Bottleneck-link bytes of an allreduce under `algo`: per-node link
    /// for `Ring` (`2(n−1)/n·B`), the leader's link for `Flat`
    /// (`2(n−1)·B`).  Time = latency + these bytes / bw, which is what
    /// lets [`CommLedger::modeled_secs`] re-price algorithms uniformly.
    pub fn allreduce_wire_bytes_with(&self, algo: Algo, n: usize, bytes: u64) -> u64 {
        if n <= 1 {
            return 0;
        }
        match algo {
            Algo::Ring => self.allreduce_wire_bytes(n, bytes),
            Algo::Flat => 2 * (n as u64 - 1) * bytes,
        }
    }

    /// Allgather: every node receives (n-1) remote chunks of `bytes`.
    pub fn allgather_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let nf = n as f64;
        (nf - 1.0) * self.alpha + (nf - 1.0) * bytes as f64 / self.bw
    }

    /// Parameter-server exchange of `bytes` per node (QSGD's model, paper
    /// §VI: quantized gradients cannot ride a summing allreduce; each
    /// node pushes its compressed gradient and pulls the aggregate —
    /// bandwidth scales with the compressed size, but the latency is NOT
    /// divided by the averaging period the way ADPSGD's is).
    pub fn ps_exchange_time(&self, n: usize, bytes: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        2.0 * self.alpha + 2.0 * bytes as f64 / self.bw
    }

    /// Bytes a PS exchange puts on the wire per node (push + pull).
    pub fn ps_exchange_wire_bytes(&self, n: usize, bytes: u64) -> u64 {
        if n <= 1 {
            return 0;
        }
        2 * bytes
    }

    /// The S_k scalar exchange (Algorithm 2 line 11).
    pub fn scalar_allreduce_time(&self, n: usize) -> f64 {
        self.allreduce_time(n, 4)
    }

    /// Bytes a ring allreduce puts on the wire per node.
    pub fn allreduce_wire_bytes(&self, n: usize, bytes: u64) -> u64 {
        if n <= 1 {
            return 0;
        }
        (2.0 * (n as f64 - 1.0) / n as f64 * bytes as f64) as u64
    }

    pub fn allgather_wire_bytes(&self, n: usize, bytes: u64) -> u64 {
        if n <= 1 {
            return 0;
        }
        (n as u64 - 1) * bytes
    }
}

// ------------------------------------------------------------- stragglers

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// |ε| < 1.15e-9 — far below the modeling error here).
pub fn inv_normal_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p = {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Expected maximum of `n` iid standard normals (Blom's order-statistic
/// approximation, accurate to ~1% for n ≥ 2; used by the heterogeneity
/// model).
pub fn e_max_normal(n: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    inv_normal_cdf((nf - 0.375) / (nf + 0.25))
}

/// Per-node compute-time heterogeneity (stragglers).
///
/// Extension of the paper's wall-clock analysis: with BSP synchronization
/// every `p` iterations, nodes wait for the slowest *sum of p steps*, not
/// the slowest single step — so periodic averaging amortizes straggler
/// noise by √p on top of saving bandwidth:
///
/// `T(K, p) = (K/p) · (p·μ + σ·√p·E[max of n normals])`
#[derive(Debug, Clone, Copy)]
pub struct ComputeModel {
    /// mean per-step compute seconds μ
    pub mu: f64,
    /// per-step jitter σ (std-dev, seconds)
    pub sigma: f64,
}

impl ComputeModel {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(mu > 0.0 && sigma >= 0.0);
        ComputeModel { mu, sigma }
    }

    /// Expected compute wall-clock of `k` iterations over `n` nodes
    /// synchronizing every `p` iterations (CLT across the p-step sums).
    pub fn bsp_compute_secs(&self, k: usize, p: usize, n: usize) -> f64 {
        if n <= 1 {
            return k as f64 * self.mu;
        }
        let p = p.max(1);
        let rounds = (k as f64 / p as f64).ceil();
        let per_round = p as f64 * self.mu + self.sigma * (p as f64).sqrt() * e_max_normal(n);
        rounds * per_round
    }

    /// Straggler *overhead* ratio vs perfectly homogeneous nodes.
    pub fn straggler_overhead(&self, k: usize, p: usize, n: usize) -> f64 {
        self.bsp_compute_secs(k, p, n) / (k as f64 * self.mu)
    }
}

/// What kind of exchange a ledger entry records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommKind {
    /// Parameter averaging (Algorithms 1/2): ring allreduce of f32[P].
    ParamAvg,
    /// Full-gradient allreduce (FULLSGD).
    GradAllreduce,
    /// Quantized-gradient allgather (QSGD).
    QuantAllgather,
    /// Sparse top-k gradient exchange (PS-style, like QSGD).
    SparsePs,
    /// The S_k scalar exchange (ADPSGD only).
    ScalarStat,
}

/// Accumulates modeled communication per category.
///
/// Stores `(count, wire bytes, secs-under-primary-net)` per kind, plus
/// the node count, so [`CommLedger::modeled_secs`] can re-price the same
/// exchanges under a *different* bandwidth preset (Fig 4c/5c/6 need both
/// 100Gbps and 10Gbps from one run).
#[derive(Debug, Clone, Default)]
pub struct CommLedger {
    pub n: usize,
    pub syncs: u64,
    /// the collective algorithm allreduce exchanges are priced as
    pub algo: Algo,
    totals: std::collections::BTreeMap<&'static str, (u64, u64, f64)>, // name -> (count, wire bytes, secs)
}

impl CommLedger {
    /// Ledger pricing allreduces with the default algorithm (ring).
    pub fn new(n: usize) -> Self {
        CommLedger { n, ..Self::default() }
    }

    /// Ledger pricing allreduces under a specific collective algorithm.
    pub fn with_algo(n: usize, algo: Algo) -> Self {
        CommLedger { n, algo, ..Self::default() }
    }

    fn kind_name(kind: CommKind) -> &'static str {
        match kind {
            CommKind::ParamAvg => "param_avg",
            CommKind::GradAllreduce => "grad_allreduce",
            CommKind::QuantAllgather => "quant_allgather",
            CommKind::SparsePs => "sparse_ps",
            CommKind::ScalarStat => "scalar_stat",
        }
    }

    /// Record one exchange of `payload` bytes over `n` nodes under `net`.
    /// Returns the modeled time for this exchange.
    pub fn record(&mut self, net: &NetModel, kind: CommKind, n: usize, payload: u64) -> f64 {
        let (wire, secs) = match kind {
            CommKind::ParamAvg | CommKind::GradAllreduce => (
                net.allreduce_wire_bytes_with(self.algo, n, payload),
                net.allreduce_time_with(self.algo, n, payload),
            ),
            CommKind::QuantAllgather | CommKind::SparsePs => {
                (net.ps_exchange_wire_bytes(n, payload), net.ps_exchange_time(n, payload))
            }
            // 4-byte exchange: latency-bound, so the algorithm's
            // bandwidth shape is irrelevant — always ring-priced
            CommKind::ScalarStat => {
                (net.allreduce_wire_bytes(n, 4), net.scalar_allreduce_time(n))
            }
        };
        if matches!(
            kind,
            CommKind::ParamAvg
                | CommKind::GradAllreduce
                | CommKind::QuantAllgather
                | CommKind::SparsePs
        ) {
            self.syncs += 1;
        }
        let e = self.totals.entry(Self::kind_name(kind)).or_insert((0, 0, 0.0));
        e.0 += 1;
        e.1 += wire;
        e.2 += secs;
        secs
    }

    /// Re-price all recorded exchanges under a different network model.
    /// Wire bytes are bandwidth-independent; the latency term is
    /// per-call and per-kind.
    pub fn modeled_secs(&self, net: &NetModel) -> f64 {
        if self.n <= 1 {
            return 0.0;
        }
        let nf = self.n as f64;
        let mut total = 0.0;
        for (name, (count, wire, _)) in &self.totals {
            let lat_per_call = match *name {
                "quant_allgather" | "sparse_ps" => 2.0 * net.alpha,
                _ => 2.0 * (nf - 1.0) * net.alpha,
            };
            total += *count as f64 * lat_per_call + *wire as f64 / net.bw;
        }
        total
    }

    pub fn total_secs(&self) -> f64 {
        self.totals.values().map(|(_, _, s)| *s).sum()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.totals.values().map(|(_, b, _)| *b).sum()
    }

    pub fn count(&self, kind: CommKind) -> u64 {
        self.totals.get(Self::kind_name(kind)).map(|e| e.0).unwrap_or(0)
    }

    pub fn secs(&self, kind: CommKind) -> f64 {
        self.totals.get(Self::kind_name(kind)).map(|e| e.2).unwrap_or(0.0)
    }

    pub fn bytes(&self, kind: CommKind) -> u64 {
        self.totals.get(Self::kind_name(kind)).map(|e| e.1).unwrap_or(0)
    }

    /// Serialize the full ledger (per-kind counts, bottleneck-link wire
    /// bytes, and modeled seconds) for the dispatch layer's run cache
    /// and worker wire format.  Round-trips through
    /// [`CommLedger::from_json`] bit-exactly for counts/bytes below
    /// 2⁵³ (JSON numbers are f64).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let totals = Json::Obj(
            self.totals
                .iter()
                .map(|(name, (count, wire, secs))| {
                    (
                        name.to_string(),
                        Json::Arr(vec![
                            Json::num(*count as f64),
                            Json::num(*wire as f64),
                            Json::num(*secs),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("syncs", Json::num(self.syncs as f64)),
            ("algo", Json::str(self.algo.to_string())),
            ("totals", totals),
        ])
    }

    /// Rebuild a ledger serialized by [`CommLedger::to_json`].
    pub fn from_json(v: &crate::util::json::Json) -> anyhow::Result<CommLedger> {
        use anyhow::{anyhow, bail};
        let num = |key: &str| -> anyhow::Result<f64> {
            v.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| anyhow!("ledger json: missing number {key:?}"))
        };
        let algo: Algo = v
            .get("algo")
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow!("ledger json: missing \"algo\""))?
            .parse()?;
        let mut ledger = CommLedger::with_algo(num("n")? as usize, algo);
        ledger.syncs = num("syncs")? as u64;
        let totals = v
            .get("totals")
            .and_then(|x| x.as_obj())
            .ok_or_else(|| anyhow!("ledger json: missing \"totals\""))?;
        const KIND_NAMES: [&'static str; 5] =
            ["param_avg", "grad_allreduce", "quant_allgather", "sparse_ps", "scalar_stat"];
        for (name, entry) in totals {
            let Some(stat) = KIND_NAMES.iter().copied().find(|k| *k == name.as_str()) else {
                bail!("ledger json: unknown exchange kind {name:?}");
            };
            let arr = entry
                .as_arr()
                .filter(|a| a.len() == 3)
                .ok_or_else(|| anyhow!("ledger json: {name:?} is not a [count, wire, secs] triple"))?;
            let f = |i: usize| arr[i].as_f64().ok_or_else(|| anyhow!("ledger json: {name:?}[{i}]"));
            ledger.totals.insert(stat, (f(0)? as u64, f(1)? as u64, f(2)?));
        }
        Ok(ledger)
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for (name, (count, bytes, secs)) in &self.totals {
            s.push_str(&format!(
                "{name:16} count={count:6} wire={:>10} time={}\n",
                crate::util::fmt::bytes(*bytes),
                crate::util::fmt::secs(*secs),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ib() -> NetModel {
        NetModel::infiniband_100g()
    }

    #[test]
    fn allreduce_time_formula() {
        let net = NetModel { bw: 1e9, alpha: 1e-6 };
        // n=4, 1e9 bytes: 2*3*1e-6 + 2*(3/4)*1.0 = 1.5 + eps
        let t = net.allreduce_time(4, 1_000_000_000);
        assert!((t - 1.500006).abs() < 1e-9, "{t}");
        assert_eq!(net.allreduce_time(1, 1 << 30), 0.0);
    }

    #[test]
    fn bandwidth_scaling() {
        let fast = ib();
        let slow = NetModel::ethernet_10g();
        let t_fast = fast.allreduce_time(16, 100 << 20);
        let t_slow = slow.allreduce_time(16, 100 << 20);
        // 10x bandwidth gap dominates for large payloads
        assert!(t_slow / t_fast > 8.0, "{t_slow} / {t_fast}");
    }

    #[test]
    fn latency_dominates_scalar() {
        let net = ib();
        let t = net.scalar_allreduce_time(16);
        // essentially 30 * alpha
        assert!((t - 30.0 * net.alpha) / t < 0.01);
    }

    #[test]
    fn allgather_more_expensive_than_allreduce_same_payload() {
        let net = ib();
        let b = 64 << 20;
        assert!(net.allgather_time(16, b) > net.allreduce_time(16, b));
    }

    #[test]
    fn ledger_accumulates() {
        let net = ib();
        let mut led = CommLedger::new(16);
        let t1 = led.record(&net, CommKind::ParamAvg, 16, 4 * 1_000_000);
        let _ = led.record(&net, CommKind::ParamAvg, 16, 4 * 1_000_000);
        let _ = led.record(&net, CommKind::ScalarStat, 16, 4);
        assert_eq!(led.syncs, 2);
        assert_eq!(led.count(CommKind::ParamAvg), 2);
        assert_eq!(led.count(CommKind::ScalarStat), 1);
        assert!((led.secs(CommKind::ParamAvg) - 2.0 * t1).abs() < 1e-12);
        assert!(led.total_secs() > 2.0 * t1);
        assert!(led.total_wire_bytes() > 0);
    }

    #[test]
    fn flat_pricing_slower_than_ring() {
        let net = ib();
        let b = 100 << 20;
        let ring = net.allreduce_time_with(Algo::Ring, 16, b);
        let flat = net.allreduce_time_with(Algo::Flat, 16, b);
        assert!(flat > ring);
        // the bandwidth term loses the 1/n pipelining factor: ratio -> n
        assert!((flat / ring - 16.0).abs() < 1.0, "{}", flat / ring);
        // ring pricing is the legacy default formula
        assert_eq!(ring, net.allreduce_time(16, b));
        // degenerate single node costs nothing under either algorithm
        assert_eq!(net.allreduce_time_with(Algo::Flat, 1, b), 0.0);
        assert_eq!(net.allreduce_wire_bytes_with(Algo::Flat, 1, b), 0);
    }

    #[test]
    fn ledger_prices_per_algorithm() {
        let net = ib();
        let mut flat = CommLedger::with_algo(8, Algo::Flat);
        let mut ring = CommLedger::with_algo(8, Algo::Ring);
        let payload = 4 * 1_000_000;
        flat.record(&net, CommKind::ParamAvg, 8, payload);
        ring.record(&net, CommKind::ParamAvg, 8, payload);
        assert!(flat.total_wire_bytes() > ring.total_wire_bytes());
        assert!(flat.total_secs() > ring.total_secs());
        // re-pricing under another bandwidth preserves the ordering
        let slow = NetModel::ethernet_10g();
        assert!(flat.modeled_secs(&slow) > ring.modeled_secs(&slow));
        // both algorithms count the exchange as one sync
        assert_eq!(flat.syncs, 1);
        assert_eq!(ring.syncs, 1);
        // the plain constructor defaults to ring pricing
        let mut d = CommLedger::new(8);
        d.record(&net, CommKind::ParamAvg, 8, payload);
        assert_eq!(d.total_wire_bytes(), ring.total_wire_bytes());
    }

    #[test]
    fn ledger_json_roundtrip_is_exact() {
        let net = ib();
        let mut led = CommLedger::with_algo(8, Algo::Flat);
        led.record(&net, CommKind::ParamAvg, 8, 4 * 1_000_000);
        led.record(&net, CommKind::ScalarStat, 8, 4);
        led.record(&net, CommKind::QuantAllgather, 8, 123_457);
        let text = led.to_json().to_string_compact();
        let back =
            CommLedger::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n, led.n);
        assert_eq!(back.syncs, led.syncs);
        assert_eq!(back.algo, led.algo);
        for kind in [
            CommKind::ParamAvg,
            CommKind::GradAllreduce,
            CommKind::QuantAllgather,
            CommKind::SparsePs,
            CommKind::ScalarStat,
        ] {
            assert_eq!(back.count(kind), led.count(kind), "{kind:?}");
            assert_eq!(back.bytes(kind), led.bytes(kind), "{kind:?}");
            assert_eq!(back.secs(kind).to_bits(), led.secs(kind).to_bits(), "{kind:?}");
        }
        // corrupted shapes are rejected, not trusted
        assert!(CommLedger::from_json(&crate::util::json::Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"algo":"ring","n":2,"syncs":1,"totals":{"mesh_avg":[1,2,3.0]}}"#;
        assert!(CommLedger::from_json(&crate::util::json::Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn e_max_normal_monotone_and_sane() {
        assert_eq!(e_max_normal(1), 0.0);
        let mut prev = 0.0;
        for n in [2usize, 4, 8, 16, 64, 256] {
            let e = e_max_normal(n);
            assert!(e > prev, "E[max] must grow with n: {e} at n={n}");
            prev = e;
        }
        // known: E[max of 16 N(0,1)] ~ 1.766
        assert!((e_max_normal(16) - 1.766).abs() < 0.15, "{}", e_max_normal(16));
    }

    #[test]
    fn periodic_averaging_amortizes_stragglers() {
        let cm = ComputeModel::new(1e-3, 2e-4);
        let k = 4000;
        let n = 16;
        let t_full = cm.bsp_compute_secs(k, 1, n);
        let t_p8 = cm.bsp_compute_secs(k, 8, n);
        // p=8 must cut the straggler overhead by ~sqrt(8)
        let ideal = k as f64 * cm.mu;
        let ov_full = t_full - ideal;
        let ov_p8 = t_p8 - ideal;
        let ratio = ov_full / ov_p8;
        assert!((ratio - 8f64.sqrt()).abs() < 0.3, "amortization ratio {ratio}");
        // single node has no straggler penalty
        assert_eq!(cm.bsp_compute_secs(k, 1, 1), ideal);
        // overhead ratio > 1 whenever sigma > 0, n > 1
        assert!(cm.straggler_overhead(k, 4, 8) > 1.0);
    }

    #[test]
    fn qsgd_byte_advantage_matches_paper() {
        // paper: QSGD 8-bit = 1/4 the data of FULLSGD; periodic averaging
        // with p=8 = 1/8.  Check the ledger reproduces those ratios.
        let net = ib();
        let p_bytes = 4 * 6_800_000u64; // GoogLeNet-ish
        let mut full = CommLedger::new(16);
        let mut qsgd = CommLedger::new(16);
        let mut adp = CommLedger::new(16);
        for k in 0..80 {
            full.record(&net, CommKind::GradAllreduce, 16, p_bytes);
            qsgd.record(&net, CommKind::QuantAllgather, 16, p_bytes / 4);
            if k % 8 == 0 {
                adp.record(&net, CommKind::ParamAvg, 16, p_bytes);
                adp.record(&net, CommKind::ScalarStat, 16, 4);
            }
        }
        let fb = full.bytes(CommKind::GradAllreduce) as f64;
        let ab = adp.bytes(CommKind::ParamAvg) as f64;
        let qb = qsgd.bytes(CommKind::QuantAllgather) as f64;
        assert!((fb / ab - 8.0).abs() < 0.2, "{}", fb / ab);
        // paper §IV-B: QSGD data = 1/4 of FULLSGD = ~2x of ADPSGD(p~8)
        assert!((fb / qb - 3.75).abs() < 0.5, "full/qsgd = {}", fb / qb);
        assert!((qb / ab - 2.13).abs() < 0.5, "qsgd/adp = {}", qb / ab);
        // QSGD saves bandwidth but not latency; with fast links its time
        // advantage over FULLSGD is less than its byte advantage.
        assert!(qsgd.total_secs() < full.total_secs());
    }
}
