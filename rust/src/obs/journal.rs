//! The structured event journal: versioned JSONL spans written next to
//! the stable campaign summary, plus the trace-id minting that lets one
//! run be followed driver → agent → worker child.
//!
//! Every line is one self-describing JSON object:
//!
//! ```json
//! {"schema":1,"ts":"2026-08-07T12:00:00.123Z","event":"run.start",
//!  "trace":"9f2c41aa03de77b1","...":"event-specific fields"}
//! ```
//!
//! `schema` is [`JOURNAL_SCHEMA`] and bumps on any incompatible line
//! shape; `ts` is ISO-8601 UTC; `event` is a dotted component name
//! (`campaign.*` from the driver, `run.*` from dispatch slots and the
//! [`JournalObserver`] bridge, `cache.*` from the run cache path).
//! `trace` is the per-run id minted by [`mint_trace_id`] at the driver
//! and propagated through proto-v5 run-request frames, so grepping one
//! id across the journal, an agent's log, and the worker protocol
//! reconstructs a single run's full path through the fabric.
//!
//! The journal is strictly an *observer*: trace ids and journal lines
//! never enter `ExperimentConfig`, cache digests, or stable summaries,
//! so summaries are byte-identical with the journal on or off, and a
//! journal write failure is counted (`obs.journal_write_errors`) but
//! never fails the run.
//!
//! Since proto v6 the same `run.*` lines also arrive *streamed* from
//! subprocess worker children and remote agents (batched
//! `Frame::Events`); [`Journal::merge_line`] validates each one and
//! splices in an `origin` field (`"node"` / `"agent:<addr>"`) so the
//! merged journal is identically shaped across local, subprocess,
//! remote, and fleet execution — lines without `origin` were bridged
//! in-process at the driver.  Invalid or undeliverable streamed lines
//! are counted in `obs.event_drops`, never retried.

use crate::coordinator::observer::{RunEvent, RunObserver};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Version of the journal line shape.  Bumps on incompatible change;
/// readers reject lines from a different schema loudly instead of
/// misreading them.
pub const JOURNAL_SCHEMA: u64 = 1;

struct JournalInner {
    w: BufWriter<File>,
    path: PathBuf,
}

/// A shared, cloneable handle on one append-only JSONL journal file.
/// Clones share the writer, so dispatch slots, the fleet poller, and
/// the driver all append to the same file without interleaving inside
/// a line.
#[derive(Clone)]
pub struct Journal {
    inner: Arc<Mutex<JournalInner>>,
}

impl std::fmt::Debug for Journal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let path = self.inner.lock().map(|i| i.path.display().to_string());
        write!(f, "Journal({})", path.as_deref().unwrap_or("<poisoned>"))
    }
}

impl Journal {
    /// Create (truncating) the journal file at `path`.
    pub fn create(path: impl Into<PathBuf>) -> Result<Journal> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating journal dir {}", dir.display()))?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        Ok(Journal { inner: Arc::new(Mutex::new(JournalInner { w: BufWriter::new(file), path })) })
    }

    /// The journal file's path.
    pub fn path(&self) -> PathBuf {
        self.inner.lock().expect("journal lock").path.clone()
    }

    /// Append one event line.  `trace` attaches the run's trace id when
    /// the event belongs to a specific run; `fields` carry the
    /// event-specific payload.  Never fails: an I/O error is counted in
    /// `obs.journal_write_errors` and the line is dropped.
    pub fn emit(&self, event: &str, trace: Option<&str>, fields: Vec<(&str, Json)>) {
        self.write_line(&render_line(event, trace, fields));
    }

    /// Merge one already-rendered journal line streamed from another
    /// executor, tagging it with `origin` (`"node"` for a subprocess
    /// worker child, `"agent:<addr>"` for a remote agent's executor).
    /// The line is validated against the schema first; an invalid line
    /// is dropped and counted in `obs.event_drops`.  Returns whether
    /// the line was merged.
    pub fn merge_line(&self, line: &str, origin: &str) -> bool {
        let trimmed = line.trim();
        if parse_line(trimmed).is_err() {
            super::metrics::metrics().counter("obs.event_drops").inc();
            return false;
        }
        // parse_line proved this is a JSON object, so it ends with '}':
        // splice the origin tag in before it, keeping every byte the
        // executor rendered (timestamps are the *executor's* clock)
        let body = &trimmed[..trimmed.len() - 1];
        let tagged =
            format!("{body},\"origin\":{}}}", Json::str(origin).to_string_compact());
        self.write_line(&tagged);
        true
    }

    /// Merge a streamed batch via [`Journal::merge_line`]; returns how
    /// many lines survived validation.
    pub fn merge_lines(&self, lines: &[String], origin: &str) -> usize {
        lines.iter().filter(|l| self.merge_line(l, origin)).count()
    }

    fn write_line(&self, line: &str) {
        let mut inner = self.inner.lock().expect("journal lock");
        let wrote = inner
            .w
            .write_all(line.as_bytes())
            .and_then(|()| inner.w.write_all(b"\n"))
            // flush per line so a crashed campaign still leaves a
            // readable journal up to the crash point
            .and_then(|()| inner.w.flush());
        if wrote.is_err() {
            super::metrics::metrics().counter("obs.journal_write_errors").inc();
        }
    }
}

/// Render one journal line (no trailing newline): the exact
/// self-describing shape [`Journal::emit`] writes.  Public so the
/// worker-side streaming bridge ([`crate::dispatch::proto`]) renders
/// lines that are indistinguishable from locally-emitted ones before
/// they ever cross a pipe or socket.
pub fn render_line(event: &str, trace: Option<&str>, fields: Vec<(&str, Json)>) -> String {
    let mut pairs = vec![
        ("schema", Json::num(JOURNAL_SCHEMA as f64)),
        ("ts", Json::str(super::now_iso8601())),
        ("event", Json::str(event)),
    ];
    if let Some(t) = trace {
        pairs.push(("trace", Json::str(t)));
    }
    pairs.extend(fields);
    Json::obj(pairs).to_string_compact()
}

/// Parse and validate one journal line against the versioned schema:
/// it must be a JSON object carrying `schema == JOURNAL_SCHEMA`, an
/// ISO-8601-shaped `ts` string, and a non-empty `event` name.  Returns
/// the parsed object so callers can inspect event-specific fields.
pub fn parse_line(line: &str) -> Result<Json> {
    let v = Json::parse(line.trim()).map_err(|e| anyhow!("journal line: {e}"))?;
    match v.get("schema").and_then(Json::as_f64) {
        Some(s) if s as u64 == JOURNAL_SCHEMA => {}
        got => {
            return Err(anyhow!(
                "journal line schema {:?} (this reader speaks {JOURNAL_SCHEMA})",
                got.map(|s| s as u64)
            ))
        }
    }
    let ts = v
        .get("ts")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("journal line without \"ts\""))?;
    if ts.len() < 20 || !ts.contains('T') || !ts.ends_with('Z') {
        return Err(anyhow!("journal line with malformed timestamp {ts:?}"));
    }
    match v.get("event").and_then(Json::as_str) {
        Some(e) if !e.is_empty() => {}
        _ => return Err(anyhow!("journal line without \"event\"")),
    }
    Ok(v)
}

/// Read every line of a journal file through [`parse_line`], failing on
/// the first malformed line (test and smoke helper).
pub fn read_all(path: &Path) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .enumerate()
        .map(|(i, l)| parse_line(l).with_context(|| format!("journal line {}", i + 1)))
        .collect()
}

static TRACE_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Mint a fresh 16-hex-char trace id: wall-clock nanos, pid, and a
/// process-local counter folded through a splitmix64 finalizer, so ids
/// are unique across concurrent runs *and* across driver processes
/// sharing one journal directory.
pub fn mint_trace_id() -> String {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let ctr = TRACE_COUNTER.fetch_add(1, Ordering::Relaxed);
    let mut z = nanos
        ^ ((std::process::id() as u64) << 32)
        ^ ctr.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    format!("{z:016x}")
}

/// Bridges the coordinator's [`RunEvent`] stream into the journal:
/// every event except the per-iteration `IterEnd` (too hot — one line
/// per training step would dwarf the rest of the journal) becomes a
/// `run.*` line carrying the run's trace id and label.
pub struct JournalObserver {
    journal: Journal,
    trace: String,
    label: String,
}

impl JournalObserver {
    pub fn new(journal: Journal, trace: impl Into<String>, label: impl Into<String>) -> Self {
        JournalObserver { journal, trace: trace.into(), label: label.into() }
    }
}

impl RunObserver for JournalObserver {
    fn on_event(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        if let Some((event, fields)) = event_fields(ev, &self.label) {
            self.journal.emit(event, Some(&self.trace), fields);
        }
        Ok(())
    }
}

/// The `run.*` journal projection of one coordinator event: its event
/// name and payload fields (including the `run` label), or `None` for
/// events the journal skips (the per-iteration `IterEnd` — one line
/// per training step would dwarf the rest of the journal).  Shared by
/// [`JournalObserver`] (driver-side thread runs) and the worker-side
/// streaming bridge, so a streamed line carries exactly the fields a
/// locally-bridged one does.
pub fn event_fields(ev: &RunEvent<'_>, label: &str) -> Option<(&'static str, Vec<(&'static str, Json)>)> {
    let label = ("run", Json::str(label));
    let arr = |xs: &[f64]| Json::Arr(xs.iter().map(|x| Json::num(*x)).collect());
    Some(match ev {
        RunEvent::RunStart { n_params, resume_iter, .. } => (
            "run.start",
            vec![
                label,
                ("n_params", Json::num(*n_params as f64)),
                ("resume_iter", Json::num(*resume_iter as f64)),
            ],
        ),
        RunEvent::IterEnd { .. } => return None,
        RunEvent::SyncDone { k, s_k, period, bytes, comm_secs, t, waits } => (
            "run.sync",
            vec![
                label,
                ("k", Json::num(*k as f64)),
                ("s_k", Json::num(*s_k)),
                ("period", Json::num(*period as f64)),
                ("bytes", Json::num(*bytes as f64)),
                ("comm_secs", Json::num(*comm_secs)),
                ("t", Json::num(*t)),
                ("waits", arr(waits)),
            ],
        ),
        RunEvent::VarProbe { k, var } => (
            "run.var_probe",
            vec![label, ("k", Json::num(*k as f64)), ("var", Json::num(*var))],
        ),
        RunEvent::EvalDone { k, loss, acc } => (
            "run.eval",
            vec![
                label,
                ("k", Json::num(*k as f64)),
                ("loss", Json::num(*loss)),
                ("acc", Json::num(*acc)),
            ],
        ),
        // metadata only: the parameter snapshot itself never enters
        // the journal
        RunEvent::CheckpointDue { iter, mean_loss, .. } => (
            "run.checkpoint",
            vec![
                label,
                ("iter", Json::num(*iter as f64)),
                ("mean_loss", Json::num(*mean_loss)),
            ],
        ),
        RunEvent::RunEnd { iters, node_secs } => (
            "run.end",
            vec![label, ("iters", Json::num(*iters as f64)), ("node_secs", arr(node_secs))],
        ),
    })
}

/// Render one coordinator event as a ready-to-merge journal line — the
/// worker-side streaming bridge's unit of work ([`crate::dispatch::
/// proto::Frame::Events`] carries batches of these).
pub fn observer_line(ev: &RunEvent<'_>, label: &str, trace: Option<&str>) -> Option<String> {
    event_fields(ev, label).map(|(event, fields)| render_line(event, trace, fields))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_journal(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("adpsgd_journal_{tag}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn trace_ids_are_hex_and_distinct() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()), "{a}");
        assert_ne!(a, b, "two mints must differ");
    }

    #[test]
    fn emitted_lines_round_trip_through_the_schema_parser() {
        let path = tmp_journal("roundtrip");
        let j = Journal::create(&path).unwrap();
        let trace = mint_trace_id();
        j.emit("campaign.start", None, vec![("runs", Json::num(3.0))]);
        j.emit("run.queued", Some(&trace), vec![("run", Json::str("r0"))]);
        let lines = read_all(&path).unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].get("event").unwrap().as_str(), Some("campaign.start"));
        assert_eq!(lines[0].get("runs").unwrap().as_f64(), Some(3.0));
        assert!(lines[0].get("trace").is_none(), "campaign events carry no trace");
        assert_eq!(lines[1].get("trace").unwrap().as_str(), Some(trace.as_str()));
        assert_eq!(
            lines[1].get("schema").unwrap().as_f64(),
            Some(JOURNAL_SCHEMA as f64)
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_line_rejects_alien_and_malformed_lines() {
        let err = parse_line("{\"schema\":99,\"ts\":\"2026-01-01T00:00:00.000Z\",\
                              \"event\":\"x\"}")
            .unwrap_err();
        assert!(format!("{err:#}").contains("schema"), "{err:#}");
        assert!(parse_line("not json").is_err());
        assert!(
            parse_line("{\"schema\":1,\"event\":\"x\"}").is_err(),
            "a line without ts must be rejected"
        );
        assert!(
            parse_line("{\"schema\":1,\"ts\":\"yesterday\",\"event\":\"x\"}").is_err(),
            "a non-ISO timestamp must be rejected"
        );
        assert!(
            parse_line("{\"schema\":1,\"ts\":\"2026-01-01T00:00:00.000Z\"}").is_err(),
            "a line without event must be rejected"
        );
    }

    #[test]
    fn journal_observer_bridges_events_and_skips_iter_end() {
        let path = tmp_journal("observer");
        let j = Journal::create(&path).unwrap();
        let trace = mint_trace_id();
        let cfg = crate::config::ExperimentConfig::default();
        let mut obs = JournalObserver::new(j, &trace, "adaptive/n8");
        obs.on_event(&RunEvent::RunStart { cfg: &cfg, n_params: 64, resume_iter: 0 }).unwrap();
        obs.on_event(&RunEvent::IterEnd { k: 0, lr: 0.1, loss: Some(1.0) }).unwrap();
        obs.on_event(&RunEvent::SyncDone {
            k: 3,
            s_k: 0.5,
            period: 4,
            bytes: 256,
            comm_secs: 2e-3,
            t: 0.05,
            waits: &[0.0, 3e-3],
        })
        .unwrap();
        obs.on_event(&RunEvent::EvalDone { k: 9, loss: 1.5, acc: 0.7 }).unwrap();
        obs.on_event(&RunEvent::RunEnd { iters: 10, node_secs: &[0.06, 0.055] }).unwrap();
        let lines = read_all(&path).unwrap();
        let events: Vec<&str> =
            lines.iter().map(|l| l.get("event").unwrap().as_str().unwrap()).collect();
        assert_eq!(
            events,
            vec!["run.start", "run.sync", "run.eval", "run.end"],
            "IterEnd must not reach the journal"
        );
        for l in &lines {
            assert_eq!(l.get("trace").unwrap().as_str(), Some(trace.as_str()));
            assert_eq!(l.get("run").unwrap().as_str(), Some("adaptive/n8"));
        }
        assert_eq!(lines[1].get("bytes").unwrap().as_f64(), Some(256.0));
        // the sync line carries the per-node attribution raw material
        assert_eq!(lines[1].get("comm_secs").unwrap().as_f64(), Some(2e-3));
        assert_eq!(lines[1].get("t").unwrap().as_f64(), Some(0.05));
        let waits = lines[1].get("waits").unwrap().as_arr().unwrap();
        assert_eq!(waits.len(), 2);
        assert_eq!(waits[1].as_f64(), Some(3e-3));
        let ends = lines[3].get("node_secs").unwrap().as_arr().unwrap();
        assert_eq!(ends[0].as_f64(), Some(0.06));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn streamed_lines_merge_with_origin_and_drops_are_counted() {
        let path = tmp_journal("merge");
        let j = Journal::create(&path).unwrap();
        let trace = mint_trace_id();
        // what a worker child would render and ship in an Events frame
        let streamed = observer_line(
            &RunEvent::RunEnd { iters: 10, node_secs: &[0.5] },
            "adaptive/n4",
            Some(&trace),
        )
        .expect("RunEnd is journaled");
        let drops = crate::obs::metrics().counter("obs.event_drops");
        let before = drops.get();
        assert!(j.merge_line(&streamed, "node"));
        assert!(!j.merge_line("not a journal line", "node"), "garbage must not merge");
        assert_eq!(
            j.merge_lines(&[streamed.clone(), "{}".into()], "agent:127.0.0.1:7070"),
            1
        );
        assert_eq!(drops.get(), before + 2, "both rejects counted");
        let lines = read_all(&path).unwrap();
        assert_eq!(lines.len(), 2, "merged lines still parse under the schema");
        assert_eq!(lines[0].get("origin").unwrap().as_str(), Some("node"));
        assert_eq!(lines[0].get("event").unwrap().as_str(), Some("run.end"));
        assert_eq!(lines[0].get("trace").unwrap().as_str(), Some(trace.as_str()));
        assert_eq!(lines[0].get("run").unwrap().as_str(), Some("adaptive/n4"));
        assert_eq!(
            lines[1].get("origin").unwrap().as_str(),
            Some("agent:127.0.0.1:7070")
        );
        // IterEnd stays unjournaled on the streaming path too
        assert!(observer_line(
            &RunEvent::IterEnd { k: 1, lr: 0.1, loss: None },
            "x",
            None
        )
        .is_none());
        std::fs::remove_file(&path).ok();
    }
}
