//! Process-wide metrics registry: named counters, gauges, and
//! histograms behind a cheap static handle.
//!
//! Instrumentation sites call [`metrics()`] once, keep the returned
//! [`Counter`] / [`Gauge`] / [`Histogram`] handle (an `Arc` around an
//! atomic), and bump it lock-free on the hot path — the registry lock
//! is taken only at registration and snapshot time.  Handles for the
//! same name share one underlying cell, so a counter bumped in the
//! dispatcher and snapshotted by `adpsgd status` agree without any
//! plumbing.
//!
//! [`Metrics::snapshot`] renders the whole registry as deterministic
//! JSON (keys sorted — the maps are `BTreeMap`s), which is what the
//! agent answers a [`crate::dispatch::proto::Frame::StatsRequest`]
//! with and what `adpsgd status --json` prints.
//!
//! Registered names in this crate (the metrics glossary):
//!
//! | name                        | kind      | meaning |
//! |-----------------------------|-----------|---------|
//! | `dispatch.queue_depth`      | gauge     | runs waiting in the dispatcher queue |
//! | `dispatch.slots_busy`       | gauge     | slot threads currently executing a run |
//! | `dispatch.cache_hits`       | counter   | runs answered from the run cache |
//! | `dispatch.cache_misses`     | counter   | runs that had to execute |
//! | `dispatch.crash_requeues`   | counter   | crashed runs put back on the queue |
//! | `dispatch.blob_bytes_staged`| counter   | warm-start snapshot bytes pushed to agents |
//! | `fleet.backoff_attempts`    | counter   | redial attempts against dropped agents |
//! | `fleet.members_joined`      | counter   | agents adopted from the registry poll |
//! | `remote.heartbeat_gap_ms`   | histogram | observed gap between remote liveness signals |
//! | `agent.runs_served`         | counter   | runs an agent daemon has answered |
//! | `agent.cache_hits`          | counter   | agent-side runs answered from its cache |
//! | `agent.blob_bytes_staged`   | counter   | blob bytes an agent accepted from dispatchers |
//! | `obs.journal_write_errors`  | counter   | journal lines dropped on I/O error |
//! | `obs.event_drops`           | counter   | streamed observer-event lines dropped (send failure, stale id, failed validation) |

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing count.  Cloning shares the cell.
#[derive(Debug, Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A signed instantaneous level (queue depth, busy slots).
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Quantile resolution: fixed log2-spaced buckets.  Bucket 0 holds
/// everything at or below `2^MIN_EXP` (including zero and negatives),
/// bucket `i` holds `(2^(MIN_EXP+i-1), 2^(MIN_EXP+i)]`, and the last
/// bucket is open-ended above.  48 buckets starting at `2^-16` span
/// ~1.5e-5 through ~4e9 — microseconds to hours whether a site
/// observes seconds or milliseconds.
const BUCKETS: usize = 48;
const MIN_EXP: i32 = -16;

fn bucket_of(v: f64) -> usize {
    if v <= (2f64).powi(MIN_EXP) {
        return 0;
    }
    // v ∈ (2^(e-1), 2^e]  ⇒  ceil(log2 v) = e
    let e = v.log2().ceil() as i32;
    ((e - MIN_EXP) as usize).min(BUCKETS - 1)
}

#[derive(Debug)]
struct HistoInner {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    buckets: [u64; BUCKETS],
}

impl Default for HistoInner {
    fn default() -> Self {
        HistoInner { count: 0, sum: 0.0, min: 0.0, max: 0.0, buckets: [0; BUCKETS] }
    }
}

impl HistoInner {
    /// Estimate the `q`-quantile from the cumulative bucket counts: the
    /// upper edge of the bucket where the cumulative count crosses the
    /// target rank, clamped into the exactly-tracked `[min, max]`.
    /// Resolution is the factor-2 bucket width — plenty for the "is p99
    /// an order of magnitude off the median?" question snapshots exist
    /// to answer.
    fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                let upper =
                    if i == 0 { self.min } else { (2f64).powi(MIN_EXP + i as i32) };
                return upper.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// A value distribution summarized as count/sum/min/max plus
/// p50/p95/p99 estimated from fixed log2-spaced buckets (factor-2
/// resolution, clamped to the exact observed range).
#[derive(Debug, Clone)]
pub struct Histogram(Arc<Mutex<HistoInner>>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let mut h = self.0.lock().expect("histogram lock");
        if h.count == 0 {
            h.min = v;
            h.max = v;
        } else {
            h.min = h.min.min(v);
            h.max = h.max.max(v);
        }
        h.count += 1;
        h.sum += v;
        h.buckets[bucket_of(v)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.0.lock().expect("histogram lock").count
    }
}

/// The registry itself.  Obtain the process-wide instance via
/// [`metrics()`].
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    /// Get (registering on first use) the counter named `name`.
    pub fn counter(&self, name: &str) -> Counter {
        self.counters
            .lock()
            .expect("metrics counters lock")
            .entry(name.to_string())
            .or_insert_with(|| Counter(Arc::new(AtomicU64::new(0))))
            .clone()
    }

    /// Get (registering on first use) the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        self.gauges
            .lock()
            .expect("metrics gauges lock")
            .entry(name.to_string())
            .or_insert_with(|| Gauge(Arc::new(AtomicI64::new(0))))
            .clone()
    }

    /// Get (registering on first use) the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Histogram {
        self.histograms
            .lock()
            .expect("metrics histograms lock")
            .entry(name.to_string())
            .or_insert_with(|| Histogram(Arc::new(Mutex::new(HistoInner::default()))))
            .clone()
    }

    /// Render every registered metric as deterministic JSON:
    /// `{"counters":{name:n,…},"gauges":{…},"histograms":{name:
    /// {"count":…,"sum":…,"min":…,"max":…,"p50":…,"p95":…,"p99":…},…}}`.
    pub fn snapshot(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .lock()
            .expect("metrics counters lock")
            .iter()
            .map(|(k, c)| (k.clone(), Json::num(c.get() as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .lock()
            .expect("metrics gauges lock")
            .iter()
            .map(|(k, g)| (k.clone(), Json::num(g.get() as f64)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .histograms
            .lock()
            .expect("metrics histograms lock")
            .iter()
            .map(|(k, h)| {
                let inner = h.0.lock().expect("histogram lock");
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::num(inner.count as f64)),
                        ("sum", Json::num(inner.sum)),
                        ("min", Json::num(if inner.count == 0 { 0.0 } else { inner.min })),
                        ("max", Json::num(if inner.count == 0 { 0.0 } else { inner.max })),
                        ("p50", Json::num(inner.quantile(0.50))),
                        ("p95", Json::num(inner.quantile(0.95))),
                        ("p99", Json::num(inner.quantile(0.99))),
                    ]),
                )
            })
            .collect();
        Json::Obj(
            [
                ("counters".to_string(), Json::Obj(counters)),
                ("gauges".to_string(), Json::Obj(gauges)),
                ("histograms".to_string(), Json::Obj(histograms)),
            ]
            .into_iter()
            .collect(),
        )
    }
}

static METRICS: OnceLock<Metrics> = OnceLock::new();

/// The process-wide metrics registry.
pub fn metrics() -> &'static Metrics {
    METRICS.get_or_init(Metrics::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_for_the_same_name_share_one_cell() {
        let m = Metrics::default();
        let a = m.counter("test.shared");
        let b = m.counter("test.shared");
        a.add(3);
        b.inc();
        assert_eq!(a.get(), 4);
        assert_eq!(b.get(), 4);
    }

    #[test]
    fn gauge_tracks_level_not_total() {
        let m = Metrics::default();
        let g = m.gauge("test.depth");
        g.add(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        g.set(0);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn histogram_summarizes_and_ignores_non_finite() {
        let m = Metrics::default();
        let h = m.histogram("test.lat");
        h.observe(2.0);
        h.observe(8.0);
        h.observe(f64::NAN);
        assert_eq!(h.count(), 2);
        let snap = m.snapshot();
        let lat = snap.get("histograms").unwrap().get("test.lat").unwrap();
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(lat.get("sum").unwrap().as_f64(), Some(10.0));
        assert_eq!(lat.get("min").unwrap().as_f64(), Some(2.0));
        assert_eq!(lat.get("max").unwrap().as_f64(), Some(8.0));
    }

    #[test]
    fn quantiles_estimate_from_log2_buckets_clamped_to_range() {
        let m = Metrics::default();
        let h = m.histogram("test.q");
        for v in 1..=100 {
            h.observe(v as f64);
        }
        let snap = m.snapshot();
        let q = snap.get("histograms").unwrap().get("test.q").unwrap();
        // rank 50 lands in the (32, 64] bucket → its upper edge
        assert_eq!(q.get("p50").unwrap().as_f64(), Some(64.0));
        // ranks 95 and 99 land in (64, 128] whose edge clamps to max
        assert_eq!(q.get("p95").unwrap().as_f64(), Some(100.0));
        assert_eq!(q.get("p99").unwrap().as_f64(), Some(100.0));
        // a single observation reports itself at every quantile
        let one = m.histogram("test.one");
        one.observe(0.25);
        let snap = m.snapshot();
        let q = snap.get("histograms").unwrap().get("test.one").unwrap();
        for p in ["p50", "p95", "p99"] {
            assert_eq!(q.get(p).unwrap().as_f64(), Some(0.25), "{p}");
        }
    }

    #[test]
    fn snapshot_is_deterministic_json() {
        let m = Metrics::default();
        m.counter("test.b").inc();
        m.counter("test.a").add(2);
        m.gauge("test.g").set(-1);
        let text = m.snapshot().to_string_compact();
        // keys sorted, one stable rendering
        assert_eq!(
            text,
            "{\"counters\":{\"test.a\":2,\"test.b\":1},\"gauges\":{\"test.g\":-1},\
             \"histograms\":{}}"
        );
        // and it round-trips through the parser
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn process_wide_handle_is_stable() {
        let c = metrics().counter("test.process_wide");
        let before = c.get();
        metrics().counter("test.process_wide").inc();
        assert_eq!(c.get(), before + 1);
    }
}
