//! # obs — process-wide observability
//!
//! One telemetry layer spanning coordinator → dispatch → fleet →
//! agent, in three pieces:
//!
//! * **Metrics** ([`metrics()`], [`Metrics`]) — named counters, gauges,
//!   and histograms behind a cheap static handle, bumped lock-free on
//!   hot paths and snapshotted to deterministic JSON on demand.  The
//!   agent daemon answers `adpsgd status` (a proto-v5 `stats_request`)
//!   with exactly this snapshot; see the glossary table in
//!   [`metrics`].
//! * **Journal** ([`Journal`], [`JournalObserver`]) — a versioned
//!   JSONL event stream (`<name>.campaign.jsonl`, written next to the
//!   stable summary) where every dispatch-fabric event (`run.queued`,
//!   `run.cache_hit`, `cache.store`, `run.crashed`, …) and every
//!   bridged coordinator [`crate::coordinator::observer::RunEvent`]
//!   lands as one self-describing line.  Lines carry the
//!   [`mint_trace_id`] per-run trace id, which also rides proto-v5
//!   run-request frames so one run is greppable driver → agent →
//!   worker child.
//!   Since proto v6 the same bridged lines stream back from subprocess
//!   workers and remote agents as batched `events` frames and merge —
//!   tagged with an `origin` — into the one journal, so it is
//!   identically shaped across local, subprocess, remote, and fleet
//!   execution.
//! * **Trace analysis** ([`trace`], `adpsgd trace`) — reconstructs
//!   per-run timelines from a campaign journal: per-node compute /
//!   comm / barrier-wait attribution of `modeled_wall_secs`, critical
//!   path, straggler histogram, and a ready-to-paste
//!   `[cluster] factors` block harvested from observed node timings.
//! * **Logging** ([`log!`](crate::obs_log), [`log_line`]) — the one
//!   diagnostic funnel for the dispatch/fleet fabric: every message
//!   gets an ISO-8601 UTC timestamp and a `[component]` tag, so
//!   interleaved output from slot threads, the fleet poller, and agent
//!   sessions stays attributable.
//!
//! Telemetry is strictly an observer of the system: nothing here ever
//! enters `ExperimentConfig`, cache digests, or stable campaign
//! summaries, which therefore stay byte-identical with telemetry on or
//! off.

pub mod journal;
pub mod metrics;
pub mod trace;

pub use journal::{mint_trace_id, parse_line, Journal, JournalObserver, JOURNAL_SCHEMA};
pub use metrics::{metrics, Counter, Gauge, Histogram, Metrics};
pub use trace::{TraceReport, TraceRun};

/// Timestamped, component-tagged diagnostic line on stderr:
/// `2026-08-07T12:00:00.123Z [dispatch] message`.  Prefer the
/// [`log!`](crate::obs_log) macro, which formats inline.
pub fn log_line(component: &str, msg: &str) {
    eprintln!("{} [{component}] {msg}", now_iso8601());
}

/// `obs::log!("component", "format {}", args…)` — the crate's one
/// diagnostic macro.  Exported at the crate root as `obs_log!` (macro
/// namespace) and re-exported here as `obs::log!`.
#[macro_export]
macro_rules! obs_log {
    ($component:expr, $($arg:tt)*) => {
        $crate::obs::log_line($component, &format!($($arg)*))
    };
}

pub use crate::obs_log as log;

/// Current wall-clock time as ISO-8601 UTC with millisecond precision.
pub fn now_iso8601() -> String {
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default();
    iso8601_from_epoch(now.as_secs(), now.subsec_millis())
}

/// Render `secs` (+ `millis`) since the Unix epoch as
/// `YYYY-MM-DDTHH:MM:SS.mmmZ` — hand-rolled (no chrono in the offline
/// registry) via the standard civil-from-days date algorithm.
pub fn iso8601_from_epoch(secs: u64, millis: u32) -> String {
    let days = (secs / 86_400) as i64;
    let rem = secs % 86_400;
    let (h, mi, s) = (rem / 3_600, (rem % 3_600) / 60, rem % 60);
    let (y, m, d) = civil_from_days(days);
    format!("{y:04}-{m:02}-{d:02}T{h:02}:{mi:02}:{s:02}.{millis:03}Z")
}

/// Proleptic-Gregorian date from days since 1970-01-01 (Howard
/// Hinnant's `civil_from_days`).
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64; // [0, 146096]
    let yoe = (doe - doe / 1_460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_epoch_instants_render_correctly() {
        assert_eq!(iso8601_from_epoch(0, 0), "1970-01-01T00:00:00.000Z");
        // 2004-02-29 leap day: 12_477 days + 12:34:56.789
        assert_eq!(iso8601_from_epoch(1_078_058_096, 789), "2004-02-29T12:34:56.789Z");
        // end-of-year rollover
        assert_eq!(iso8601_from_epoch(1_767_225_599, 999), "2025-12-31T23:59:59.999Z");
        assert_eq!(iso8601_from_epoch(1_767_225_600, 0), "2026-01-01T00:00:00.000Z");
    }

    #[test]
    fn now_is_iso_shaped() {
        let ts = now_iso8601();
        assert_eq!(ts.len(), 24, "{ts}");
        assert_eq!(&ts[4..5], "-");
        assert_eq!(&ts[10..11], "T");
        assert!(ts.ends_with('Z'), "{ts}");
    }

    #[test]
    fn log_macro_formats_through_the_funnel() {
        // smoke: must compile with both plain and formatted arguments
        crate::obs::log!("test", "plain message");
        crate::obs::log!("test", "run {} finished in {:.1}s", 7, 1.25);
    }
}
