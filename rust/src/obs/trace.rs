//! `adpsgd trace` — reconstruct per-run timelines from a campaign
//! journal.
//!
//! The proto-v6 streaming path (see [`super::journal`]) lands every
//! run's bridged observer events in the one `<name>.campaign.jsonl`
//! regardless of where the run executed.  Two of those events carry the
//! raw material for a full time attribution:
//!
//! * `run.sync` — per completed sync: the modeled wire cost
//!   `comm_secs`, the post-sync cluster clock `t`, and the per-node
//!   barrier-wait seconds `waits` accumulated since the previous sync
//!   (all from the replicated
//!   [`crate::netsim::cluster::ClusterClock`]);
//! * `run.end` — every node's final modeled clock `node_secs`.
//!
//! From these, each run's `modeled_wall_secs` decomposes *exactly* into
//! per-node compute / barrier-wait / comm buckets: over sync round `j`
//! (clock interval `t_{j-1} → t_j`) node `i` computed
//! `(t_j − comm_j − waits_ij) − t_{j-1}` seconds, waited `waits_ij`,
//! and spent `comm_j` communicating; the tail after the last sync is
//! pure compute (`node_secs_i − t_last`).  The round's *straggler* is
//! the node that arrived at the barrier last — the one with the
//! smallest wait — and the critical path is the chain of straggler
//! compute plus wire time that actually bounds the modeled wall clock.
//!
//! [`TraceReport::emit_cluster`] closes the loop back into config: the
//! observed per-node compute totals, normalized so the fastest node is
//! `1.0`, are exactly the `[cluster] factors` table
//! ([`crate::netsim::cluster::ClusterModel`]) that would *replay* the
//! observed skew — harvested factors are validated through the real
//! config parser before they are printed, so the block is
//! paste-ready.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeSet;
use std::path::Path;

/// One sync round reconstructed from a `run.sync` line.
#[derive(Debug, Clone)]
struct SyncRound {
    /// iteration index the sync fired at (ordering key)
    k: f64,
    comm_secs: f64,
    /// post-sync modeled cluster clock
    t: f64,
    /// per-node barrier-wait seconds accumulated since the last sync
    waits: Vec<f64>,
}

/// One run's reconstructed timeline.
#[derive(Debug, Clone)]
pub struct TraceRun {
    pub label: String,
    pub trace: Option<String>,
    /// distinct `origin` tags seen on this run's lines (empty = every
    /// line was bridged in-process at the driver)
    pub origins: Vec<String>,
    /// dispatch slot that executed the run (`thread`, `subprocess`,
    /// `remote:<addr>`), from the dispatch-side `run.start` line
    pub slot: Option<String>,
    /// answered from the run cache — no training, no timeline
    pub from_cache: bool,
    /// queue depth stamped on this run's `run.queued` line
    pub queue_depth: Option<f64>,
    /// completed syncs seen (`run.sync` lines)
    pub syncs: usize,
    /// nodes, from the `run.end` clock vector (0 = no timeline)
    pub nodes: usize,
    /// max over nodes of the final modeled clock; falls back to the
    /// `run.done` summary field for runs without streamed events
    pub modeled_wall_secs: f64,
    /// per-node compute seconds (sync intervals + post-sync tail)
    pub node_compute: Vec<f64>,
    /// per-node barrier-wait seconds
    pub node_wait: Vec<f64>,
    /// total modeled wire seconds (shared by all nodes)
    pub comm_secs: f64,
    /// straggler-chain compute + wire time — what actually bounds the
    /// modeled wall clock
    pub critical_path_secs: f64,
    /// per node: rounds where it arrived at the barrier last
    pub straggler_rounds: Vec<usize>,
}

impl TraceRun {
    fn new(label: String, trace: Option<String>) -> TraceRun {
        TraceRun {
            label,
            trace,
            origins: Vec::new(),
            slot: None,
            from_cache: false,
            queue_depth: None,
            syncs: 0,
            nodes: 0,
            modeled_wall_secs: 0.0,
            node_compute: Vec::new(),
            node_wait: Vec::new(),
            comm_secs: 0.0,
            critical_path_secs: 0.0,
            straggler_rounds: Vec::new(),
        }
    }

    /// Whether the journal carried enough streamed events to attribute
    /// this run's time per node.
    pub fn attributed(&self) -> bool {
        self.nodes > 0
    }

    /// Observed per-node relative compute factors, fastest node = 1.0
    /// (`None` when the run has no timeline or a zero-compute node).
    pub fn observed_factors(&self) -> Option<Vec<f64>> {
        if !self.attributed() {
            return None;
        }
        let min = self.node_compute.iter().cloned().fold(f64::INFINITY, f64::min);
        if !min.is_finite() || min <= 0.0 {
            return None;
        }
        Some(self.node_compute.iter().map(|c| c / min).collect())
    }

    fn to_json(&self) -> Json {
        let arr = |xs: &[f64]| Json::Arr(xs.iter().map(|x| Json::num(*x)).collect());
        let mut pairs = vec![
            ("run", Json::str(self.label.clone())),
            (
                "trace",
                self.trace.as_ref().map(|t| Json::str(t.clone())).unwrap_or(Json::Null),
            ),
            (
                "origins",
                Json::Arr(self.origins.iter().map(|o| Json::str(o.clone())).collect()),
            ),
            (
                "slot",
                self.slot.as_ref().map(|s| Json::str(s.clone())).unwrap_or(Json::Null),
            ),
            ("from_cache", Json::Bool(self.from_cache)),
            (
                "queue_depth",
                self.queue_depth.map(Json::num).unwrap_or(Json::Null),
            ),
            ("syncs", Json::num(self.syncs as f64)),
            ("modeled_wall_secs", Json::num(self.modeled_wall_secs)),
        ];
        if self.attributed() {
            pairs.push(("nodes", Json::num(self.nodes as f64)));
            pairs.push(("node_compute_secs", arr(&self.node_compute)));
            pairs.push(("node_wait_secs", arr(&self.node_wait)));
            pairs.push(("comm_secs", Json::num(self.comm_secs)));
            pairs.push(("critical_path_secs", Json::num(self.critical_path_secs)));
            pairs.push((
                "straggler_rounds",
                Json::Arr(
                    self.straggler_rounds.iter().map(|r| Json::num(*r as f64)).collect(),
                ),
            ));
            if let Some(f) = self.observed_factors() {
                pairs.push(("observed_factors", arr(&f)));
            }
        }
        Json::obj(pairs)
    }
}

/// The analyzed timeline of one campaign journal.
#[derive(Debug, Clone)]
pub struct TraceReport {
    /// campaign name, from `campaign.start`
    pub campaign: Option<String>,
    /// runs in journal (queue) order
    pub runs: Vec<TraceRun>,
}

/// Per-run accumulator while scanning journal lines.
struct RunAcc {
    run: TraceRun,
    rounds: Vec<SyncRound>,
    node_secs: Vec<f64>,
    /// `run.done` summary fallback for cache hits / unstreamed runs
    done_wall: Option<f64>,
}

/// Analyze a campaign journal file (see [`analyze`]).
pub fn analyze_file(path: &Path) -> Result<TraceReport> {
    let lines = super::journal::read_all(path)
        .with_context(|| format!("reading campaign journal {}", path.display()))?;
    analyze(&lines)
}

/// Group a journal's lines per run (by trace id, falling back to the
/// run label), reconstruct each run's sync rounds, and attribute its
/// modeled wall clock into per-node compute / wait / comm buckets.
pub fn analyze(lines: &[Json]) -> Result<TraceReport> {
    let mut campaign = None;
    let mut accs: Vec<RunAcc> = Vec::new();
    for line in lines {
        let event = line.get("event").and_then(Json::as_str).unwrap_or("");
        if event == "campaign.start" {
            if let Some(name) = line.get("campaign").and_then(Json::as_str) {
                campaign = Some(name.to_string());
            }
            continue;
        }
        let Some(label) = line.get("run").and_then(Json::as_str) else { continue };
        let trace = line.get("trace").and_then(Json::as_str).map(str::to_string);
        // the trace id is the run's identity when present (two sweep
        // points can share a label across re-runs); label otherwise
        let idx = accs
            .iter()
            .position(|a| match (&a.run.trace, &trace) {
                (Some(a), Some(b)) => a == b,
                _ => a.run.label == label,
            })
            .unwrap_or_else(|| {
                accs.push(RunAcc {
                    run: TraceRun::new(label.to_string(), trace.clone()),
                    rounds: Vec::new(),
                    node_secs: Vec::new(),
                    done_wall: None,
                });
                accs.len() - 1
            });
        let acc = &mut accs[idx];
        if let Some(origin) = line.get("origin").and_then(Json::as_str) {
            if !acc.run.origins.iter().any(|o| o == origin) {
                acc.run.origins.push(origin.to_string());
            }
        }
        match event {
            "run.queued" => {
                acc.run.queue_depth = line.get("queue_depth").and_then(Json::as_f64);
            }
            "run.start" => {
                // two events share this name: the dispatch lifecycle
                // line (has `slot`) and the bridged observer line (has
                // `n_params`); only the former names the executor
                if let Some(slot) = line.get("slot").and_then(Json::as_str) {
                    acc.run.slot = Some(slot.to_string());
                }
            }
            "run.cache_hit" => acc.run.from_cache = true,
            "run.sync" => {
                let waits = line
                    .get("waits")
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().filter_map(Json::as_f64).collect())
                    .unwrap_or_default();
                acc.rounds.push(SyncRound {
                    k: line.get("k").and_then(Json::as_f64).unwrap_or(0.0),
                    comm_secs: line.get("comm_secs").and_then(Json::as_f64).unwrap_or(0.0),
                    t: line.get("t").and_then(Json::as_f64).unwrap_or(0.0),
                    waits,
                });
            }
            "run.end" => {
                if let Some(ns) = line.get("node_secs").and_then(Json::as_arr) {
                    acc.node_secs = ns.iter().filter_map(Json::as_f64).collect();
                }
            }
            "run.done" => {
                acc.done_wall = line.get("modeled_wall_secs").and_then(Json::as_f64);
            }
            _ => {}
        }
    }
    let runs = accs.into_iter().map(attribute).collect();
    Ok(TraceReport { campaign, runs })
}

/// Close one run's books: walk its sync rounds in clock order and
/// split every node's final clock into compute, barrier wait, and
/// comm.
fn attribute(mut acc: RunAcc) -> TraceRun {
    let run = &mut acc.run;
    run.syncs = acc.rounds.len();
    let n = acc.node_secs.len();
    if n == 0 {
        // no streamed run.end: only the dispatch summary is available
        run.modeled_wall_secs = acc.done_wall.unwrap_or(0.0);
        return acc.run;
    }
    run.nodes = n;
    run.node_compute = vec![0.0; n];
    run.node_wait = vec![0.0; n];
    run.straggler_rounds = vec![0; n];
    acc.rounds.sort_by(|a, b| {
        a.k.partial_cmp(&b.k).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut prev_t = 0.0;
    for round in &acc.rounds {
        run.comm_secs += round.comm_secs;
        run.critical_path_secs += round.comm_secs;
        let mut slowest = 0usize;
        let mut slowest_wait = f64::INFINITY;
        let mut max_compute: f64 = 0.0;
        for i in 0..n {
            let wait = round.waits.get(i).copied().unwrap_or(0.0);
            // node i reached this barrier at (t − comm − wait): the
            // clock interval minus its wait and the wire time is what
            // it spent computing
            let compute = ((round.t - round.comm_secs - wait) - prev_t).max(0.0);
            run.node_compute[i] += compute;
            run.node_wait[i] += wait;
            max_compute = max_compute.max(compute);
            if wait < slowest_wait {
                slowest_wait = wait;
                slowest = i;
            }
        }
        // the straggler — smallest wait — is the arrival the barrier
        // (and therefore the wall clock) actually waited for
        run.straggler_rounds[slowest] += 1;
        run.critical_path_secs += max_compute;
        prev_t = round.t;
    }
    // tail after the last sync is pure compute
    let mut max_tail: f64 = 0.0;
    for i in 0..n {
        let tail = (acc.node_secs[i] - prev_t).max(0.0);
        run.node_compute[i] += tail;
        max_tail = max_tail.max(tail);
    }
    run.critical_path_secs += max_tail;
    run.modeled_wall_secs =
        acc.node_secs.iter().cloned().fold(0.0, f64::max);
    acc.run
}

impl TraceReport {
    /// Machine-readable form (`adpsgd trace --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "campaign",
                self.campaign
                    .as_ref()
                    .map(|c| Json::str(c.clone()))
                    .unwrap_or(Json::Null),
            ),
            ("runs", Json::Arr(self.runs.iter().map(TraceRun::to_json).collect())),
        ])
    }

    /// The human table: one block per run, with the per-node breakdown
    /// for every run the journal carried streamed events for.
    pub fn render(&self) -> String {
        let mut out = String::new();
        match &self.campaign {
            Some(c) => out.push_str(&format!(
                "== trace: campaign {c:?} ({} runs) ==\n",
                self.runs.len()
            )),
            None => out.push_str(&format!("== trace: {} runs ==\n", self.runs.len())),
        }
        for run in &self.runs {
            out.push('\n');
            out.push_str(&format!("run {:?}", run.label));
            if let Some(t) = &run.trace {
                out.push_str(&format!("  trace {t}"));
            }
            if let Some(s) = &run.slot {
                out.push_str(&format!("  slot {s}"));
            }
            if !run.origins.is_empty() {
                out.push_str(&format!("  origin {}", run.origins.join(",")));
            }
            out.push('\n');
            if run.from_cache {
                out.push_str("  answered from cache (no timeline)\n");
                continue;
            }
            out.push_str(&format!(
                "  modeled wall {:>10.6}s  comm {:>10.6}s  syncs {:>4}",
                run.modeled_wall_secs, run.comm_secs, run.syncs
            ));
            if let Some(d) = run.queue_depth {
                out.push_str(&format!("  queued at depth {d:.0}"));
            }
            out.push('\n');
            if !run.attributed() {
                out.push_str("  (no streamed run.sync/run.end events: per-node attribution unavailable)\n");
                continue;
            }
            out.push_str(&format!(
                "  critical path {:.6}s ({:.1}% of wall)\n",
                run.critical_path_secs,
                100.0 * run.critical_path_secs / run.modeled_wall_secs.max(f64::MIN_POSITIVE),
            ));
            let factors = run.observed_factors();
            out.push_str("  node   compute(s)     wait(s)   factor  straggled\n");
            for i in 0..run.nodes {
                out.push_str(&format!(
                    "  {:>4}  {:>11.6} {:>11.6}  {}  {:>3} of {} rounds\n",
                    i,
                    run.node_compute[i],
                    run.node_wait[i],
                    factors
                        .as_ref()
                        .map(|f| format!("{:>7.2}", f[i]))
                        .unwrap_or_else(|| "      -".into()),
                    run.straggler_rounds[i],
                    run.syncs,
                ));
            }
        }
        out
    }

    /// Harvest the observed per-node skew as a paste-ready
    /// `[cluster] factors` TOML block ([`crate::netsim::cluster`]):
    /// per-rank mean of each attributed run's observed factors
    /// (fastest node = 1.0), over the runs with the journal's modal
    /// node count.  The block is round-tripped through the real config
    /// parser and [`crate::netsim::cluster::ClusterModel::from_config`]
    /// before it is returned — what this prints, a config file
    /// accepts.
    pub fn emit_cluster(&self) -> Result<String> {
        let observed: Vec<(usize, Vec<f64>)> = self
            .runs
            .iter()
            .filter_map(|r| r.observed_factors().map(|f| (r.nodes, f)))
            .collect();
        if observed.is_empty() {
            bail!(
                "no run in this journal carried streamed run.sync/run.end events \
                 (re-run the campaign with event streaming on, without --no-stream)"
            );
        }
        // modal node count wins: a sweep mixing cluster sizes harvests
        // the size most of its runs used
        let counts: BTreeSet<usize> = observed.iter().map(|(n, _)| *n).collect();
        let n = counts
            .iter()
            .copied()
            .max_by_key(|n| observed.iter().filter(|(m, _)| m == n).count())
            .expect("nonempty observed");
        let picked: Vec<&Vec<f64>> =
            observed.iter().filter(|(m, _)| *m == n).map(|(_, f)| f).collect();
        let mut mean = vec![0.0f64; n];
        for f in &picked {
            for i in 0..n {
                mean[i] += f[i] / picked.len() as f64;
            }
        }
        // re-normalize after averaging so the fastest rank is exactly 1
        let min = mean.iter().cloned().fold(f64::INFINITY, f64::min);
        let factors: Vec<String> =
            mean.iter().map(|f| format!("{:.4}", f / min)).collect();
        let block = format!("[cluster]\nfactors = [{}]\n", factors.join(", "));
        // round-trip: the emitted block must be accepted verbatim by
        // the config layer and build a valid cluster model for n nodes
        let doc = crate::config::toml::TomlDoc::parse(&block)
            .map_err(|e| anyhow!("emitted cluster block does not parse: {e}"))?;
        let mut cfg = crate::config::ExperimentConfig::default();
        cfg.nodes = n;
        cfg.apply_doc(&doc).context("emitted cluster block rejected by the config layer")?;
        crate::netsim::cluster::ClusterModel::from_config(
            &cfg.cluster,
            &cfg.net,
            n,
            1,
            0,
        )
        .context("emitted factors rejected by the cluster model")?;
        Ok(block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::journal::render_line;

    /// Journal lines for one synthetic 2-node run: one sync round at
    /// t=0.005 (comm 1ms; node 0 waited 3ms, node 1 arrived last),
    /// final clocks 0.006 / 0.007.  Hand-checked attribution:
    /// compute = [0.002, 0.006], wait = [0.003, 0.0], comm 0.001.
    fn synthetic_run(label: &str, trace: &str, origin: Option<&str>) -> Vec<Json> {
        let lines = vec![
            render_line(
                "run.queued",
                Some(trace),
                vec![("run", Json::str(label)), ("queue_depth", Json::num(2.0))],
            ),
            render_line(
                "run.start",
                Some(trace),
                vec![
                    ("run", Json::str(label)),
                    ("slot", Json::str("thread")),
                    ("attempt", Json::num(1.0)),
                ],
            ),
            render_line(
                "run.sync",
                Some(trace),
                vec![
                    ("run", Json::str(label)),
                    ("k", Json::num(3.0)),
                    ("s_k", Json::num(0.5)),
                    ("period", Json::num(4.0)),
                    ("bytes", Json::num(256.0)),
                    ("comm_secs", Json::num(1e-3)),
                    ("t", Json::num(5e-3)),
                    ("waits", Json::Arr(vec![Json::num(3e-3), Json::num(0.0)])),
                ],
            ),
            render_line(
                "run.end",
                Some(trace),
                vec![
                    ("run", Json::str(label)),
                    ("iters", Json::num(10.0)),
                    (
                        "node_secs",
                        Json::Arr(vec![Json::num(6e-3), Json::num(7e-3)]),
                    ),
                ],
            ),
            render_line(
                "run.done",
                Some(trace),
                vec![
                    ("run", Json::str(label)),
                    ("modeled_wall_secs", Json::num(7e-3)),
                    ("syncs", Json::num(1.0)),
                ],
            ),
        ];
        lines
            .into_iter()
            .map(|l| match origin {
                Some(o) => {
                    let body = &l[..l.len() - 1];
                    Json::parse(&format!(
                        "{body},\"origin\":{}}}",
                        Json::str(o).to_string_compact()
                    ))
                    .unwrap()
                }
                None => Json::parse(&l).unwrap(),
            })
            .collect()
    }

    fn close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
    }

    #[test]
    fn attribution_decomposes_the_modeled_wall_clock() {
        let mut lines = vec![Json::parse(&render_line(
            "campaign.start",
            None,
            vec![("campaign", Json::str("bench")), ("runs", Json::num(1.0))],
        ))
        .unwrap()];
        lines.extend(synthetic_run("skew/n2", "aaaa000011112222", Some("node")));
        let report = analyze(&lines).unwrap();
        assert_eq!(report.campaign.as_deref(), Some("bench"));
        assert_eq!(report.runs.len(), 1);
        let run = &report.runs[0];
        assert_eq!(run.label, "skew/n2");
        assert_eq!(run.trace.as_deref(), Some("aaaa000011112222"));
        assert_eq!(run.origins, vec!["node".to_string()]);
        assert_eq!(run.slot.as_deref(), Some("thread"));
        assert_eq!(run.queue_depth, Some(2.0));
        assert_eq!(run.syncs, 1);
        assert_eq!(run.nodes, 2);
        close(run.modeled_wall_secs, 7e-3);
        close(run.comm_secs, 1e-3);
        // round 1: node 0 computed (5−1−3)=1ms, node 1 (5−1−0)=4ms;
        // tail: 1ms / 2ms
        close(run.node_compute[0], 2e-3);
        close(run.node_compute[1], 6e-3);
        close(run.node_wait[0], 3e-3);
        close(run.node_wait[1], 0.0);
        // node 1 arrived last (zero wait) → it straggled the round
        assert_eq!(run.straggler_rounds, vec![0, 1]);
        // critical path = straggler compute 4ms + comm 1ms + max tail
        // 2ms = the wall clock exactly (barrier model)
        close(run.critical_path_secs, 7e-3);
        // per-node books close: compute + wait + comm = final clock
        for i in 0..2 {
            close(
                run.node_compute[i] + run.node_wait[i] + run.comm_secs,
                [6e-3, 7e-3][i],
            );
        }
        let factors = run.observed_factors().unwrap();
        close(factors[0], 1.0);
        close(factors[1], 3.0);
        // both render paths mention the run
        assert!(report.render().contains("skew/n2"));
        let js = report.to_json().to_string_compact();
        assert!(js.contains("\"critical_path_secs\""), "{js}");
    }

    #[test]
    fn unstreamed_runs_fall_back_to_the_dispatch_summary() {
        let trace = "bbbb000011112222";
        let lines: Vec<Json> = [
            render_line(
                "run.queued",
                Some(trace),
                vec![("run", Json::str("plain")), ("queue_depth", Json::num(1.0))],
            ),
            render_line(
                "run.done",
                Some(trace),
                vec![
                    ("run", Json::str("plain")),
                    ("modeled_wall_secs", Json::num(0.25)),
                    ("syncs", Json::num(4.0)),
                ],
            ),
        ]
        .iter()
        .map(|l| Json::parse(l).unwrap())
        .collect();
        let report = analyze(&lines).unwrap();
        let run = &report.runs[0];
        assert!(!run.attributed());
        close(run.modeled_wall_secs, 0.25);
        assert!(report.render().contains("attribution unavailable"));
        // cache hits render as such
        let hit = Json::parse(&render_line(
            "run.cache_hit",
            Some("cccc000011112222"),
            vec![("run", Json::str("warm")), ("digest", Json::str("d"))],
        ))
        .unwrap();
        let report = analyze(&[hit]).unwrap();
        assert!(report.runs[0].from_cache);
        assert!(report.render().contains("answered from cache"));
    }

    #[test]
    fn emit_cluster_round_trips_through_the_config_parser() {
        let mut lines = synthetic_run("a", "aaaa000011112222", Some("node"));
        lines.extend(synthetic_run("b", "dddd000011112222", None));
        let report = analyze(&lines).unwrap();
        let block = report.emit_cluster().unwrap();
        assert!(block.starts_with("[cluster]\n"), "{block}");
        assert!(block.contains("factors = [1.0000, 3.0000]"), "{block}");
        // and the printed block really is accepted by the config layer
        let doc = crate::config::toml::TomlDoc::parse(&block).unwrap();
        let mut cfg = crate::config::ExperimentConfig::default();
        cfg.nodes = 2;
        cfg.apply_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.factors, vec![1.0, 3.0]);
        let model = crate::netsim::cluster::ClusterModel::from_config(
            &cfg.cluster,
            &cfg.net,
            2,
            1,
            0,
        )
        .unwrap();
        assert_eq!(model.factors, vec![1.0, 3.0]);
    }

    #[test]
    fn emit_cluster_without_streamed_events_is_a_clear_error() {
        let line = Json::parse(&render_line(
            "run.done",
            Some("eeee000011112222"),
            vec![("run", Json::str("x")), ("modeled_wall_secs", Json::num(1.0))],
        ))
        .unwrap();
        let err = analyze(&[line]).unwrap().emit_cluster().unwrap_err();
        assert!(format!("{err:#}").contains("streamed"), "{err:#}");
    }
}
