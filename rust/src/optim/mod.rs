//! Optimizer pieces: learning-rate schedules + (pure-rust) momentum SGD.
//!
//! The paper's experiments use two schedules:
//! * CIFAR (§IV-B): γ₀ = 0.1, ×0.1 at epochs 80/120 of 160 — we express
//!   boundaries in iterations (2000/3000 of 4000 in the figures).
//! * ImageNet (§IV-C): gradual warmup (γ from 0.1 to 0.8 over 8 epochs)
//!   then ×0.1 steps — the `Warmup` schedule.

use crate::config::LrSchedule;

/// Evaluate the schedule at iteration `k`.
pub fn lr_at(schedule: &LrSchedule, lr0: f32, k: usize) -> f32 {
    match schedule {
        LrSchedule::Const => lr0,
        LrSchedule::StepDecay { boundaries, factor } => {
            let drops = boundaries.iter().filter(|&&b| k >= b).count() as i32;
            lr0 * factor.powi(drops)
        }
        LrSchedule::Warmup { warmup_iters, warmup_factor, boundaries, factor } => {
            let peak = lr0 * warmup_factor;
            if k < *warmup_iters && *warmup_iters > 0 {
                // linear ramp lr0 -> peak (paper: +0.1/epoch from 0.1 to 0.8)
                let t = k as f32 / *warmup_iters as f32;
                lr0 + (peak - lr0) * t
            } else {
                let drops = boundaries.iter().filter(|&&b| k >= b).count() as i32;
                peak * factor.powi(drops)
            }
        }
    }
}

/// Momentum-SGD state for the pure-rust workload path (the HLO path
/// applies the fused Pallas kernel inside the `step` artifact instead).
#[derive(Debug, Clone)]
pub struct MomentumSgd {
    pub momentum: f32,
    pub velocity: Vec<f32>,
}

impl MomentumSgd {
    pub fn new(n_params: usize, momentum: f32) -> Self {
        MomentumSgd { momentum, velocity: vec![0.0; n_params] }
    }

    /// w -= lr * (mu * v + g);  v' = mu * v + g   (paper / PyTorch form).
    pub fn step(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        crate::tensor::momentum_update(w, &mut self.velocity, g, lr, self.momentum);
    }

    pub fn reset(&mut self) {
        self.velocity.iter_mut().for_each(|v| *v = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_schedule() {
        assert_eq!(lr_at(&LrSchedule::Const, 0.1, 0), 0.1);
        assert_eq!(lr_at(&LrSchedule::Const, 0.1, 99999), 0.1);
    }

    #[test]
    fn step_decay_paper_cifar() {
        let s = LrSchedule::StepDecay { boundaries: vec![2000, 3000], factor: 0.1 };
        assert!((lr_at(&s, 0.1, 0) - 0.1).abs() < 1e-9);
        assert!((lr_at(&s, 0.1, 1999) - 0.1).abs() < 1e-9);
        assert!((lr_at(&s, 0.1, 2000) - 0.01).abs() < 1e-9);
        assert!((lr_at(&s, 0.1, 3000) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn warmup_ramps_then_decays() {
        let s = LrSchedule::Warmup {
            warmup_iters: 100,
            warmup_factor: 8.0,
            boundaries: vec![300, 600],
            factor: 0.1,
        };
        assert!((lr_at(&s, 0.1, 0) - 0.1).abs() < 1e-6);
        let mid = lr_at(&s, 0.1, 50);
        assert!(mid > 0.1 && mid < 0.8, "{mid}");
        assert!((lr_at(&s, 0.1, 100) - 0.8).abs() < 1e-6);
        assert!((lr_at(&s, 0.1, 300) - 0.08).abs() < 1e-6);
        assert!((lr_at(&s, 0.1, 600) - 0.008).abs() < 1e-6);
    }

    #[test]
    fn momentum_sgd_converges_on_quadratic() {
        // minimize ||w||^2/2: g = w
        let mut w = vec![1.0f32; 8];
        let mut opt = MomentumSgd::new(8, 0.9);
        for _ in 0..200 {
            let g = w.clone();
            opt.step(&mut w, &g, 0.05);
        }
        assert!(crate::tensor::sq_norm(&w) < 1e-6);
    }

    #[test]
    fn momentum_zero_is_sgd() {
        let mut w = vec![2.0f32];
        let mut opt = MomentumSgd::new(1, 0.0);
        opt.step(&mut w, &[1.0], 0.5);
        assert!((w[0] - 1.5).abs() < 1e-7);
    }
}
