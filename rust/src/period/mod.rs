//! Averaging-period controllers — the paper's contribution lives here.
//!
//! * [`Constant`] — Algorithm 1 (CPSGD): sync every `p` iterations.
//! * [`Adaptive`] — Algorithm 2 (ADPSGD): warmup epoch at p=1, then
//!   p = p_init while sampling `C₂ = avg(S_k/γ_k)` for `k < K_s`, then
//!   grow/shrink p by 1 to keep `S_k ≈ γ_k·C₂` within [0.7, 1.3]
//!   thresholds.
//! * [`Decreasing`] — the Wang & Joshi-style strawman the paper rebuts
//!   in §V-B (large period first, small period later).
//! * `Full` synchronization and QSGD are *modes* of the coordinator,
//!   not period controllers (they exchange gradients every iteration).

pub mod registry;

use anyhow::bail;

/// Config-level strategy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// FULLSGD: gradient allreduce every iteration.
    Full,
    /// CPSGD: constant period (Algorithm 1).
    Constant,
    /// ADPSGD: adaptive period (Algorithm 2).
    Adaptive,
    /// §V-B strawman: decreasing period.
    Decreasing,
    /// QSGD: quantized gradient exchange every iteration.
    Qsgd,
    /// Explicit piecewise period schedule ("0:4,2000:8" — the paper's
    /// §III-A strategy-1/2 experiments).
    Piecewise,
    /// EASGD (Zhang et al., the paper's [57]): periodic *elastic*
    /// averaging — each node moves a fraction α toward the mean instead
    /// of adopting it.
    Easgd,
    /// Top-k gradient sparsification with error feedback (Strom [12] /
    /// Aji & Heafield [53] family, §VI): every iteration, compressed.
    TopK,
    /// AdaComm (Wang & Joshi, arXiv 1810.08313): error-runtime-optimal
    /// decaying schedule τ = ceil(τ₀·√(F(w)/F(w₀))) re-derived from the
    /// current loss at every sync.
    AdaComm,
    /// Parallel Restarted SGD (Yu, Yang & Zhu, arXiv 1807.06629):
    /// constant-period averaging with momentum *restarted* at every
    /// averaging point.
    PrSgd,
    /// DaSGD delayed averaging (Zhu et al., arXiv 2006.00441): the
    /// allreduce launched at a sync point is applied `delay` iterations
    /// later, overlapping communication with continued local steps.
    DaSgd,
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "full" | "fullsgd" => Strategy::Full,
            "constant" | "cpsgd" => Strategy::Constant,
            "adaptive" | "adpsgd" => Strategy::Adaptive,
            "decreasing" => Strategy::Decreasing,
            "qsgd" => Strategy::Qsgd,
            "piecewise" => Strategy::Piecewise,
            "easgd" => Strategy::Easgd,
            "topk" => Strategy::TopK,
            "adacomm" => Strategy::AdaComm,
            "prsgd" | "pr_sgd" => Strategy::PrSgd,
            "dasgd" => Strategy::DaSgd,
            other => bail!(
                "unknown strategy {other:?} \
                 (full|constant|adaptive|decreasing|qsgd|piecewise|easgd|topk|\
                  adacomm|prsgd|dasgd)"
            ),
        })
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Strategy::Full => "FULLSGD",
            Strategy::Constant => "CPSGD",
            Strategy::Adaptive => "ADPSGD",
            Strategy::Decreasing => "DECREASING",
            Strategy::Qsgd => "QSGD",
            Strategy::Piecewise => "PIECEWISE",
            Strategy::Easgd => "EASGD",
            Strategy::TopK => "TOPK",
            Strategy::AdaComm => "ADACOMM",
            Strategy::PrSgd => "PRSGD",
            Strategy::DaSgd => "DASGD",
        };
        f.write_str(s)
    }
}

/// Snapshot of a period controller's adaptive state, carried inside
/// parameter checkpoints so a warm start resumes Algorithm 2 *exactly*:
/// the sampled `C₂` running average and the current period `p` survive
/// the restart instead of being re-seeded from the first post-resume
/// sync.
///
/// The fields are a superset: schedule-only controllers use `period`
/// and `cnt` (the phase inside the current period) and leave the C₂
/// fields zero.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CtrlState {
    /// current averaging period p
    pub period: u64,
    /// iterations into the current period (sync-counter phase)
    pub cnt: u64,
    /// ADPSGD: the sampled C₂ running average (Algorithm 2 line 14)
    pub c2: f64,
    /// ADPSGD: how many samples the running average has absorbed
    pub c2_samples: u64,
}

/// Decides, after each local update `k`, whether to synchronize now, and
/// adapts from the post-sync feedback `(S_k, γ_k)`.
///
/// `k` is the **global** iteration index: when a run warm-starts from a
/// checkpoint (`init_from`), the coordinator passes
/// `resumed_iter + local_k`, so a controller's k-dependent state (the
/// ADPSGD warmup window, C₂ sampling horizon, schedule switch points)
/// continues where the checkpointed run left off instead of restarting
/// at iteration 0.
pub trait PeriodController: Send {
    /// Called after the local update of iteration `k` (0-based, global).
    fn should_sync(&mut self, k: usize) -> bool;

    /// Feedback after a synchronization at iteration `k`: the measured
    /// parameter variance `S_k` and the learning rate in effect.
    fn on_sync(&mut self, k: usize, s_k: f64, lr: f32);

    /// Current period (for logging / Fig 3).
    fn current_period(&self) -> usize;

    fn name(&self) -> &'static str;

    /// Snapshot the controller's adaptive state for a checkpoint.
    /// `None` (the default) means the controller is stateless beyond its
    /// configuration and needs nothing restored.
    fn snapshot(&self) -> Option<CtrlState> {
        None
    }

    /// Restore a state previously produced by [`Self::snapshot`] (from a
    /// checkpoint of the same strategy).  The default ignores it.
    fn restore(&mut self, _state: &CtrlState) {}

    /// Does this controller adapt from the (globally agreed) training
    /// loss?  When true, the coordinator allreduces the mean local loss
    /// at every sync (charged to the ledger as a scalar stat) and feeds
    /// it to [`Self::observe_loss`] — so every rank derives the same
    /// schedule from the same number.  Default: no loss feedback.
    fn wants_loss(&self) -> bool {
        false
    }

    /// Globally agreed loss at a sync point (only called when
    /// [`Self::wants_loss`] is true).  Default: ignored.
    fn observe_loss(&mut self, _loss: f64) {}
}

// ---------------------------------------------------------------- constant

/// Algorithm 1: sync every `p` iterations.
#[derive(Debug, Clone)]
pub struct Constant {
    p: usize,
    cnt: usize,
}

impl Constant {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1);
        Constant { p, cnt: 0 }
    }
}

impl PeriodController for Constant {
    fn should_sync(&mut self, _k: usize) -> bool {
        self.cnt += 1;
        if self.cnt == self.p {
            self.cnt = 0;
            true
        } else {
            false
        }
    }

    fn on_sync(&mut self, _k: usize, _s_k: f64, _lr: f32) {}

    fn current_period(&self) -> usize {
        self.p
    }

    fn name(&self) -> &'static str {
        "constant"
    }

    fn snapshot(&self) -> Option<CtrlState> {
        Some(CtrlState { period: self.p as u64, cnt: self.cnt as u64, ..CtrlState::default() })
    }

    fn restore(&mut self, state: &CtrlState) {
        // p is configuration; only the phase inside the period resumes.
        // Clamp by modulo: a snapshot taken under a larger period (or a
        // resume that lowers `p`) must not leave cnt >= p, which would
        // never equal p in should_sync and silence syncing entirely.
        self.cnt = state.cnt as usize % self.p;
    }
}

// ---------------------------------------------------------------- adaptive

/// Algorithm 2 (ADPSGD).
///
/// State machine:
/// 1. `k < warmup_iters`: p = 1 ("averaging period of 1 for the first
///    epoch", §IV-B) — avoids the large initial variance of Fig 1.
/// 2. `k < k_s`: p = p_init; every sync accumulates the running average
///    `C₂ ← avg(S_k / γ_k)` (line 14).
/// 3. after sampling: if `S_k < low·γ_k·C₂` then p += 1; if
///    `S_k > high·γ_k·C₂` then p = max(1, p−1) (lines 16–19).
#[derive(Debug, Clone)]
pub struct Adaptive {
    pub p_init: usize,
    pub warmup_iters: usize,
    pub k_s: usize,
    pub low: f64,
    pub high: f64,
    p: usize,
    cnt: usize,
    c2: f64,
    c2_samples: u64,
}

impl Adaptive {
    pub fn new(p_init: usize, warmup_iters: usize, k_s: usize, low: f64, high: f64) -> Self {
        assert!(p_init >= 1 && low < 1.0 && high > 1.0);
        Adaptive { p_init, warmup_iters, k_s, low, high, p: p_init, cnt: 0, c2: 0.0, c2_samples: 0 }
    }

    /// The sampled C₂ (for tests / introspection).
    pub fn c2(&self) -> f64 {
        self.c2
    }
}

impl PeriodController for Adaptive {
    fn should_sync(&mut self, k: usize) -> bool {
        if k < self.warmup_iters {
            // warmup epoch: p = 1, counter stays reset
            self.cnt = 0;
            return true;
        }
        self.cnt += 1;
        if self.cnt >= self.p {
            self.cnt = 0;
            true
        } else {
            false
        }
    }

    fn on_sync(&mut self, k: usize, s_k: f64, lr: f32) {
        if k < self.warmup_iters {
            return; // warmup syncs don't train C2 (p=1 variance is tiny)
        }
        let gamma = lr as f64;
        if gamma <= 0.0 {
            return;
        }
        if k < self.k_s {
            // RUNNINGAVERAGE(C2, S_k / gamma_k)  (Algorithm 2 line 14)
            self.c2_samples += 1;
            self.c2 += (s_k / gamma - self.c2) / self.c2_samples as f64;
            return;
        }
        if self.c2_samples == 0 {
            // never sampled (k_s <= warmup); fall back to first observation
            self.c2 = s_k / gamma;
            self.c2_samples = 1;
            return;
        }
        let target = gamma * self.c2;
        if s_k < self.low * target {
            self.p += 1; // line 17
        } else if s_k > self.high * target {
            self.p = (self.p - 1).max(1); // line 19
        }
    }

    fn current_period(&self) -> usize {
        self.p
    }

    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn snapshot(&self) -> Option<CtrlState> {
        Some(CtrlState {
            period: self.p as u64,
            cnt: self.cnt as u64,
            c2: self.c2,
            c2_samples: self.c2_samples,
        })
    }

    fn restore(&mut self, state: &CtrlState) {
        self.p = (state.period as usize).max(1);
        self.cnt = state.cnt as usize;
        self.c2 = state.c2;
        self.c2_samples = state.c2_samples;
    }
}

// ---------------------------------------------------------------- adacomm

/// AdaComm (Wang & Joshi, arXiv 1810.08313): communication period
/// derived from the error-runtime trade-off,
/// `τ(t) = ceil(τ₀ · sqrt(F(w_t) / F(w_0)))`, re-evaluated from the
/// globally agreed training loss at every sync and clamped to
/// `[1, τ₀]`.  Loss decays ⇒ the period *decays* toward 1 — the inverse
/// of ADPSGD's growth, which is exactly why the comparison under skew
/// is interesting.
///
/// Until the first loss observation arrives the controller runs at τ₀.
/// The reference loss `F(w_0)` is the first observed value; it persists
/// across warm starts through [`CtrlState`] (`c2` carries `f0`,
/// `c2_samples` carries the have-reference flag), so a resumed run keeps
/// the original normalization instead of re-anchoring to the already
/// decayed loss.
#[derive(Debug, Clone)]
pub struct AdaComm {
    pub tau0: usize,
    f0: f64,
    have_f0: bool,
    p: usize,
    cnt: usize,
}

impl AdaComm {
    pub fn new(tau0: usize) -> Self {
        assert!(tau0 >= 1);
        AdaComm { tau0, f0: 0.0, have_f0: false, p: tau0, cnt: 0 }
    }

    /// The reference loss F(w_0) (for tests / introspection).
    pub fn f0(&self) -> Option<f64> {
        self.have_f0.then_some(self.f0)
    }
}

impl PeriodController for AdaComm {
    fn should_sync(&mut self, _k: usize) -> bool {
        self.cnt += 1;
        if self.cnt >= self.p {
            self.cnt = 0;
            true
        } else {
            false
        }
    }

    fn on_sync(&mut self, _k: usize, _s_k: f64, _lr: f32) {}

    fn current_period(&self) -> usize {
        self.p
    }

    fn name(&self) -> &'static str {
        "adacomm"
    }

    fn wants_loss(&self) -> bool {
        true
    }

    fn observe_loss(&mut self, loss: f64) {
        if !loss.is_finite() || loss <= 0.0 {
            return; // divergence / degenerate loss: hold the period
        }
        if !self.have_f0 {
            self.f0 = loss;
            self.have_f0 = true;
            return;
        }
        let tau = (self.tau0 as f64) * (loss / self.f0).sqrt();
        self.p = (tau.ceil() as usize).clamp(1, self.tau0);
        self.cnt = self.cnt.min(self.p - 1);
    }

    fn snapshot(&self) -> Option<CtrlState> {
        Some(CtrlState {
            period: self.p as u64,
            cnt: self.cnt as u64,
            c2: self.f0,
            c2_samples: self.have_f0 as u64,
        })
    }

    fn restore(&mut self, state: &CtrlState) {
        self.p = (state.period as usize).clamp(1, self.tau0);
        self.cnt = state.cnt as usize % self.p;
        self.f0 = state.c2;
        self.have_f0 = state.c2_samples > 0;
    }
}

// -------------------------------------------------------------- decreasing

/// §V-B strawman: period `first` for the first half of training, then
/// `second` (paper: 20 then 5, same comm budget as CPSGD p=8).
#[derive(Debug, Clone)]
pub struct Decreasing {
    pub first: usize,
    pub second: usize,
    pub switch_at: usize,
    cnt: usize,
}

impl Decreasing {
    pub fn new(first: usize, second: usize, switch_at: usize) -> Self {
        assert!(first >= 1 && second >= 1);
        Decreasing { first, second, switch_at, cnt: 0 }
    }

    fn period_at(&self, k: usize) -> usize {
        if k < self.switch_at {
            self.first
        } else {
            self.second
        }
    }
}

impl PeriodController for Decreasing {
    fn should_sync(&mut self, k: usize) -> bool {
        self.cnt += 1;
        if self.cnt >= self.period_at(k) {
            self.cnt = 0;
            true
        } else {
            false
        }
    }

    fn on_sync(&mut self, _k: usize, _s_k: f64, _lr: f32) {}

    fn current_period(&self) -> usize {
        // report the phase-1 period until the switch; callers log per-k
        self.first
    }

    fn name(&self) -> &'static str {
        "decreasing"
    }

    fn snapshot(&self) -> Option<CtrlState> {
        Some(CtrlState {
            period: self.first as u64,
            cnt: self.cnt as u64,
            ..CtrlState::default()
        })
    }

    fn restore(&mut self, state: &CtrlState) {
        self.cnt = state.cnt as usize;
    }
}

// --------------------------------------------------------------- piecewise

/// Explicit piecewise-constant schedule: a sorted list of
/// `(start_iter, period)` segments.  This is how the paper's §III-A
/// strategy-1 ("p=4 for the first 2000 iterations, then p=8") and
/// strategy-2 are expressed, and how external schedules (e.g. tuned
/// offline) plug in.
#[derive(Debug, Clone)]
pub struct Piecewise {
    /// (start_iter, period), sorted by start_iter, first entry at 0
    pub segments: Vec<(usize, usize)>,
    cnt: usize,
}

impl Piecewise {
    pub fn new(mut segments: Vec<(usize, usize)>) -> anyhow::Result<Self> {
        if segments.is_empty() {
            bail!("piecewise schedule needs at least one segment");
        }
        segments.sort_by_key(|s| s.0);
        if segments[0].0 != 0 {
            bail!("piecewise schedule must start at iteration 0");
        }
        if segments.iter().any(|&(_, p)| p == 0) {
            bail!("piecewise periods must be >= 1");
        }
        if segments.windows(2).any(|w| w[0].0 == w[1].0) {
            bail!("duplicate piecewise segment start");
        }
        Ok(Piecewise { segments, cnt: 0 })
    }

    /// Parse "0:4,2000:8" (iter:period pairs).
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut segs = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, p) = part
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("bad segment {part:?} (want iter:period)"))?;
            segs.push((k.trim().parse::<usize>()?, p.trim().parse::<usize>()?));
        }
        Self::new(segs)
    }

    fn period_at(&self, k: usize) -> usize {
        let mut p = self.segments[0].1;
        for &(start, period) in &self.segments {
            if k >= start {
                p = period;
            } else {
                break;
            }
        }
        p
    }
}

impl PeriodController for Piecewise {
    fn should_sync(&mut self, k: usize) -> bool {
        self.cnt += 1;
        if self.cnt >= self.period_at(k) {
            self.cnt = 0;
            true
        } else {
            false
        }
    }

    fn on_sync(&mut self, _k: usize, _s_k: f64, _lr: f32) {}

    fn current_period(&self) -> usize {
        self.segments[0].1
    }

    fn name(&self) -> &'static str {
        "piecewise"
    }

    fn snapshot(&self) -> Option<CtrlState> {
        Some(CtrlState {
            period: self.segments[0].1 as u64,
            cnt: self.cnt as u64,
            ..CtrlState::default()
        })
    }

    fn restore(&mut self, state: &CtrlState) {
        self.cnt = state.cnt as usize;
    }
}

// Controllers are built through [`registry::build`] from a typed
// `StrategySpec` plus a `Ctx` carrying the *global* iteration horizon
// (warm starts pass `resume + iters`); see
// `coordinator::sync::SyncStep::build` for the single production call
// site.  There is deliberately no `build(cfg)` convenience here — it
// would not know the resume offset and would silently diverge from the
// coordinator on warm starts.

#[cfg(test)]
mod tests {
    use super::*;

    fn sync_points(ctrl: &mut dyn PeriodController, iters: usize) -> Vec<usize> {
        (0..iters).filter(|&k| ctrl.should_sync(k)).collect()
    }

    #[test]
    fn constant_period_sync_schedule() {
        let mut c = Constant::new(4);
        let pts = sync_points(&mut c, 16);
        assert_eq!(pts, vec![3, 7, 11, 15]);
    }

    #[test]
    fn constant_p1_syncs_every_iter() {
        let mut c = Constant::new(1);
        assert_eq!(sync_points(&mut c, 5).len(), 5);
    }

    #[test]
    fn adaptive_warmup_syncs_every_iter() {
        let mut a = Adaptive::new(4, 10, 100, 0.7, 1.3);
        let pts = sync_points(&mut a, 10);
        assert_eq!(pts.len(), 10, "warmup must sync every iteration");
    }

    #[test]
    fn adaptive_samples_c2_then_grows_period() {
        let mut a = Adaptive::new(4, 0, 40, 0.7, 1.3);
        let lr = 0.1f32;
        // sampling phase: S_k / lr = 2.0 -> C2 = 2.0
        let mut k = 0;
        while k < 40 {
            if a.should_sync(k) {
                a.on_sync(k, 0.2, lr);
            }
            k += 1;
        }
        assert!((a.c2() - 2.0).abs() < 1e-6); // f32 lr -> ~1e-8 slack
        assert_eq!(a.current_period(), 4);
        // post-sampling: tiny S_k -> period grows by 1 per sync
        let mut grown = 0;
        while k < 140 {
            if a.should_sync(k) {
                a.on_sync(k, 0.001, lr);
                grown += 1;
            }
            k += 1;
        }
        assert!(a.current_period() > 4, "period should grow, got {}", a.current_period());
        assert!(grown >= 2);
    }

    #[test]
    fn adaptive_shrinks_on_large_variance() {
        let mut a = Adaptive::new(6, 0, 12, 0.7, 1.3);
        let lr = 0.1f32;
        let mut k = 0;
        while k < 12 {
            if a.should_sync(k) {
                a.on_sync(k, 0.1, lr); // C2 = 1.0
            }
            k += 1;
        }
        while k < 60 {
            if a.should_sync(k) {
                a.on_sync(k, 10.0, lr); // way above high threshold
            }
            k += 1;
        }
        assert_eq!(a.current_period(), 1, "period should shrink to 1");
    }

    #[test]
    fn adaptive_holds_period_in_band() {
        let mut a = Adaptive::new(5, 0, 10, 0.7, 1.3);
        let lr = 0.1f32;
        let mut k = 0;
        while k < 10 {
            if a.should_sync(k) {
                a.on_sync(k, 0.05, lr); // C2 = 0.5
            }
            k += 1;
        }
        while k < 100 {
            if a.should_sync(k) {
                a.on_sync(k, 0.05, lr); // exactly at target -> inside band
            }
            k += 1;
        }
        assert_eq!(a.current_period(), 5, "in-band S_k must not change p");
    }

    #[test]
    fn adaptive_period_never_below_one() {
        let mut a = Adaptive::new(1, 0, 2, 0.7, 1.3);
        let mut k = 0;
        while k < 50 {
            if a.should_sync(k) {
                a.on_sync(k, 100.0, 0.1);
            }
            k += 1;
        }
        assert_eq!(a.current_period(), 1);
    }

    #[test]
    fn adaptive_snapshot_restore_resumes_exactly() {
        // drive one controller for 200 iters; snapshot at 100 into a
        // fresh controller; both must take identical decisions after
        let feedback = |k: usize| if k < 40 { 0.2 } else { 0.02 };
        let mut full = Adaptive::new(4, 0, 40, 0.7, 1.3);
        let mut snap: Option<CtrlState> = None;
        let mut tail_full = Vec::new();
        for k in 0..200 {
            if full.should_sync(k) {
                full.on_sync(k, feedback(k), 0.1);
            }
            if k + 1 == 100 {
                snap = full.snapshot();
            }
            if k >= 100 {
                tail_full.push((k, full.current_period()));
            }
        }
        let snap = snap.expect("adaptive snapshots");
        assert!(snap.c2_samples > 0, "C₂ was sampled before the snapshot");
        let mut resumed = Adaptive::new(4, 0, 40, 0.7, 1.3);
        resumed.restore(&snap);
        assert!((resumed.c2() - snap.c2).abs() == 0.0);
        let mut tail_resumed = Vec::new();
        for k in 100..200 {
            if resumed.should_sync(k) {
                resumed.on_sync(k, feedback(k), 0.1);
            }
            tail_resumed.push((k, resumed.current_period()));
        }
        assert_eq!(tail_full, tail_resumed, "restored controller must continue exactly");
    }

    #[test]
    fn constant_restore_clamps_phase_from_a_larger_period() {
        // snapshot under p=8 mid-period, resume with p=4: the phase must
        // wrap, not exceed the new period (cnt >= p would never sync)
        let mut big = Constant::new(8);
        for k in 0..5 {
            big.should_sync(k);
        }
        let st = big.snapshot().unwrap();
        assert_eq!(st.cnt, 5);
        let mut small = Constant::new(4);
        small.restore(&st);
        let first_sync = (0..16).find(|&k| small.should_sync(k));
        assert_eq!(first_sync, Some(2), "cnt wraps to 1; syncs 3 iters later");
    }

    #[test]
    fn schedule_controllers_snapshot_phase() {
        let mut c = Constant::new(4);
        for k in 0..6 {
            c.should_sync(k);
        }
        let st = c.snapshot().unwrap();
        assert_eq!(st.cnt, 2, "2 iters into the current period");
        let mut c2 = Constant::new(4);
        c2.restore(&st);
        // next sync arrives after the remaining 2 iterations
        assert!(!c2.should_sync(6));
        assert!(c2.should_sync(7));
    }

    #[test]
    fn decreasing_switches_period() {
        let mut d = Decreasing::new(4, 2, 8);
        let pts = sync_points(&mut d, 16);
        assert_eq!(pts, vec![3, 7, 9, 11, 13, 15]);
    }

    #[test]
    fn piecewise_parse_and_schedule() {
        let mut p = Piecewise::parse("0:4, 2000:8").unwrap();
        assert_eq!(p.segments, vec![(0, 4), (2000, 8)]);
        let syncs = (0..4000).filter(|&k| p.should_sync(k)).count();
        assert_eq!(syncs, 750, "paper §III-A strategy-1 budget");
    }

    #[test]
    fn piecewise_rejects_bad_specs() {
        assert!(Piecewise::parse("").is_err());
        assert!(Piecewise::parse("5:4").is_err(), "must start at 0");
        assert!(Piecewise::parse("0:0").is_err(), "period 0");
        assert!(Piecewise::parse("0:4,0:8").is_err(), "duplicate start");
        assert!(Piecewise::parse("0-4").is_err(), "bad separator");
    }

    #[test]
    fn piecewise_parse_error_paths() {
        // empty / effectively-empty specs
        assert!(Piecewise::parse("").is_err(), "empty spec");
        assert!(Piecewise::parse("  ").is_err(), "whitespace-only spec");
        assert!(Piecewise::parse(",,,").is_err(), "only separators");
        // zero period anywhere in the schedule
        assert!(Piecewise::parse("0:0").is_err(), "zero period");
        assert!(Piecewise::parse("0:4,100:0").is_err(), "zero period later");
        // non-monotonic switch points: duplicates are rejected ...
        assert!(Piecewise::parse("0:4,0:8").is_err(), "duplicate switch point");
        assert!(Piecewise::parse("0:4,50:2,50:8").is_err(), "later duplicate");
        // ... while merely-unsorted input is normalized by sorting
        let p = Piecewise::parse("2000:8,0:4").unwrap();
        assert_eq!(p.segments, vec![(0, 4), (2000, 8)]);
        // malformed numbers / separators
        assert!(Piecewise::parse("0:abc").is_err(), "non-numeric period");
        assert!(Piecewise::parse("-5:4").is_err(), "negative iteration");
        assert!(Piecewise::parse("0:-4").is_err(), "negative period");
        assert!(Piecewise::parse("0=4").is_err(), "wrong separator");
        // must cover iteration 0
        assert!(Piecewise::parse("5:4").is_err(), "first segment after 0");
    }

    #[test]
    fn piecewise_single_segment_is_constant() {
        let mut p = Piecewise::parse("0:5").unwrap();
        let mut c = Constant::new(5);
        for k in 0..200 {
            assert_eq!(p.should_sync(k), c.should_sync(k), "k={k}");
        }
    }

    #[test]
    fn strategy_parsing() {
        assert_eq!("adpsgd".parse::<Strategy>().unwrap(), Strategy::Adaptive);
        assert_eq!("cpsgd".parse::<Strategy>().unwrap(), Strategy::Constant);
        assert_eq!("full".parse::<Strategy>().unwrap(), Strategy::Full);
        assert_eq!("piecewise".parse::<Strategy>().unwrap(), Strategy::Piecewise);
        assert_eq!("easgd".parse::<Strategy>().unwrap(), Strategy::Easgd);
        assert_eq!("adacomm".parse::<Strategy>().unwrap(), Strategy::AdaComm);
        assert_eq!("prsgd".parse::<Strategy>().unwrap(), Strategy::PrSgd);
        assert_eq!("pr_sgd".parse::<Strategy>().unwrap(), Strategy::PrSgd);
        assert_eq!("dasgd".parse::<Strategy>().unwrap(), Strategy::DaSgd);
        let err = "nope".parse::<Strategy>().unwrap_err().to_string();
        assert!(err.contains("adacomm") && err.contains("dasgd"), "{err}");
    }

    #[test]
    fn adacomm_runs_at_tau0_until_first_loss() {
        let mut a = AdaComm::new(8);
        assert!(a.wants_loss(), "adacomm consumes loss feedback");
        let pts = sync_points(&mut a, 24);
        assert_eq!(pts, vec![7, 15, 23], "no loss seen -> constant tau0");
        assert_eq!(a.f0(), None);
    }

    #[test]
    fn adacomm_period_decays_with_the_loss() {
        let mut a = AdaComm::new(16);
        a.observe_loss(2.0); // sets the reference F(w_0)
        assert_eq!(a.f0(), Some(2.0));
        assert_eq!(a.current_period(), 16);
        a.observe_loss(2.0); // F = F0 -> tau = tau0
        assert_eq!(a.current_period(), 16);
        a.observe_loss(0.5); // sqrt(0.25) = 0.5 -> ceil(8)
        assert_eq!(a.current_period(), 8);
        a.observe_loss(0.02); // sqrt(0.01) = 0.1 -> ceil(1.6) = 2
        assert_eq!(a.current_period(), 2);
        a.observe_loss(1e-9); // floor at 1
        assert_eq!(a.current_period(), 1);
        a.observe_loss(50.0); // loss spike above F0: clamped to tau0
        assert_eq!(a.current_period(), 16);
    }

    #[test]
    fn adacomm_ignores_degenerate_loss() {
        let mut a = AdaComm::new(8);
        a.observe_loss(f64::NAN);
        a.observe_loss(-1.0);
        a.observe_loss(0.0);
        assert_eq!(a.f0(), None, "degenerate values must not anchor F(w_0)");
        a.observe_loss(1.0);
        a.observe_loss(f64::INFINITY); // divergence: hold current period
        assert_eq!(a.current_period(), 8);
    }

    #[test]
    fn adacomm_snapshot_restore_keeps_reference_loss() {
        let mut a = AdaComm::new(16);
        a.observe_loss(4.0);
        a.observe_loss(1.0); // tau = 16 * sqrt(1/4) = 8
        for k in 0..5 {
            a.should_sync(k);
        }
        let st = a.snapshot().unwrap();
        assert_eq!(st.period, 8);
        assert_eq!(st.c2, 4.0, "f0 rides in the c2 slot");
        assert_eq!(st.c2_samples, 1);
        let mut b = AdaComm::new(16);
        b.restore(&st);
        assert_eq!(b.f0(), Some(4.0));
        assert_eq!(b.current_period(), 8);
        // the restored controller keeps normalizing against the original
        // F(w_0), not the loss at resume time
        b.observe_loss(0.25); // 16 * sqrt(1/16) = 4
        assert_eq!(b.current_period(), 4);
        // phase resumed too: 5 iters into p=8 -> next sync 3 iters later
        let mut c = AdaComm::new(16);
        c.restore(&st);
        let first = (0..16).find(|&k| c.should_sync(k));
        assert_eq!(first, Some(2));
    }

    #[test]
    fn paper_communication_budget_example() {
        // §III-A: strategy-1 (p=4 then p=8 over 4000 iters, switch at 2000)
        // performs 750 syncs; CPSGD p=5 performs 800.
        let mut s1_syncs = 0;
        let mut inc = Decreasing::new(4, 8, 2000); // increasing period via Decreasing(first<second)
        for k in 0..4000 {
            if inc.should_sync(k) {
                s1_syncs += 1;
            }
        }
        assert_eq!(s1_syncs, 750);
        let mut c5 = Constant::new(5);
        let c5_syncs = (0..4000).filter(|&k| c5.should_sync(k)).count();
        assert_eq!(c5_syncs, 800);
    }
}
