//! Open registry of period controllers, keyed by canonical strategy
//! name.
//!
//! The coordinator never matches on [`crate::period::Strategy`] to pick
//! a controller: it asks the registry to build one from the typed
//! [`StrategySpec`], and dispatches through the [`PeriodController`]
//! trait from then on.  New schedules plug in two ways:
//!
//! * **replace a builtin** — a [`Registry`] instance with
//!   [`Registry::register`] swaps the builder for a name (e.g. an
//!   experimental Adaptive variant behind the same `adaptive` spec);
//! * **bypass the registry entirely** — sessions can inject a custom
//!   controller factory via
//!   `ExperimentBuilder::period_controller`, which
//!   takes precedence over the registry and needs no spec at all.
//!
//! Gradient-mode strategies (FULLSGD / QSGD / TopK) have no period
//! controller — their builders return `None`, which the sync pipeline
//! reads as "exchange every iteration".

use super::{AdaComm, Adaptive, Constant, Decreasing, PeriodController, Piecewise};
use crate::config::StrategySpec;
use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Build-time context a controller may need beyond its own knobs.
#[derive(Debug, Clone, Copy)]
pub struct Ctx {
    /// Total iterations K of *this* run (ADPSGD's sampling horizon
    /// `K_s = ks_frac·K` and the decreasing schedule's switch point are
    /// fractions of it).
    pub total_iters: usize,
}

/// A named controller builder.  Returns `None` when the spec runs in
/// gradient mode (no period gate).
pub type BuilderFn = fn(&StrategySpec, &Ctx) -> Option<Box<dyn PeriodController>>;

/// A name → builder table.  [`Registry::with_defaults`] carries the
/// paper's controllers; callers may re-register names to swap
/// implementations.
pub struct Registry {
    builders: BTreeMap<String, BuilderFn>,
}

fn build_none(_: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
    None
}

fn build_constant(spec: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
    match spec {
        StrategySpec::Constant { period } => Some(Box::new(Constant::new(*period))),
        _ => None,
    }
}

fn build_adaptive(spec: &StrategySpec, ctx: &Ctx) -> Option<Box<dyn PeriodController>> {
    match spec {
        StrategySpec::Adaptive { p_init, warmup_iters, ks_frac, low, high } => {
            let k_s = (ks_frac * ctx.total_iters as f64) as usize;
            Some(Box::new(Adaptive::new(*p_init, *warmup_iters, k_s, *low, *high)))
        }
        _ => None,
    }
}

fn build_decreasing(spec: &StrategySpec, ctx: &Ctx) -> Option<Box<dyn PeriodController>> {
    match spec {
        StrategySpec::Decreasing { first, second } => {
            Some(Box::new(Decreasing::new(*first, *second, ctx.total_iters / 2)))
        }
        _ => None,
    }
}

fn build_piecewise(spec: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
    match spec {
        StrategySpec::Piecewise { schedule } => Some(Box::new(
            Piecewise::parse(schedule).expect("validated piecewise schedule"),
        )),
        _ => None,
    }
}

fn build_easgd(spec: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
    // EASGD syncs on a constant period; the elastic pull is a pipeline
    // stage in the coordinator, not a scheduling concern
    match spec {
        StrategySpec::Easgd { period, .. } => Some(Box::new(Constant::new(*period))),
        _ => None,
    }
}

fn build_adacomm(spec: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
    match spec {
        StrategySpec::AdaComm { tau0 } => Some(Box::new(AdaComm::new(*tau0))),
        _ => None,
    }
}

fn build_prsgd(spec: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
    // PR-SGD schedules like CPSGD; the momentum restart at each
    // averaging point is a SyncStep pipeline flag, not a schedule
    match spec {
        StrategySpec::PrSgd { period } => Some(Box::new(Constant::new(*period))),
        _ => None,
    }
}

fn build_dasgd(spec: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
    // DaSGD *launches* an average on a constant period; the delayed
    // apply lives in the SyncStep pipeline (overlap is a clock/ledger
    // concern, never a parameter-math concern)
    match spec {
        StrategySpec::DaSgd { period, .. } => Some(Box::new(Constant::new(*period))),
        _ => None,
    }
}

impl Registry {
    /// The paper's controllers under their canonical names.
    pub fn with_defaults() -> Registry {
        let mut r = Registry { builders: BTreeMap::new() };
        r.register("full", build_none);
        r.register("constant", build_constant);
        r.register("adaptive", build_adaptive);
        r.register("decreasing", build_decreasing);
        r.register("qsgd", build_none);
        r.register("piecewise", build_piecewise);
        r.register("easgd", build_easgd);
        r.register("topk", build_none);
        r.register("adacomm", build_adacomm);
        r.register("prsgd", build_prsgd);
        r.register("dasgd", build_dasgd);
        r
    }

    /// Register (or replace) the builder for a strategy name.
    pub fn register(&mut self, name: &str, f: BuilderFn) {
        self.builders.insert(name.to_string(), f);
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.builders.keys().map(String::as_str)
    }

    /// Build the controller for a spec, dispatching by its canonical
    /// name.  `None` for gradient-mode strategies or unknown names.
    pub fn build(&self, spec: &StrategySpec, ctx: &Ctx) -> Option<Box<dyn PeriodController>> {
        self.builders.get(spec.name()).and_then(|f| f(spec, ctx))
    }
}

/// Build from the process-wide default registry (the builtins).
pub fn build(spec: &StrategySpec, ctx: &Ctx) -> Option<Box<dyn PeriodController>> {
    static DEFAULT: OnceLock<Registry> = OnceLock::new();
    DEFAULT.get_or_init(Registry::with_defaults).build(spec, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::period::Strategy;

    #[test]
    fn defaults_cover_every_strategy() {
        let r = Registry::with_defaults();
        let ctx = Ctx { total_iters: 4000 };
        for kind in crate::config::spec::ALL_STRATEGIES {
            let spec = StrategySpec::default_of(kind);
            let ctrl = r.build(&spec, &ctx);
            match kind {
                Strategy::Full | Strategy::Qsgd | Strategy::TopK => {
                    assert!(ctrl.is_none(), "{kind} is gradient-mode")
                }
                _ => assert!(ctrl.is_some(), "{kind} needs a controller"),
            }
        }
    }

    #[test]
    fn adaptive_horizon_scales_with_total_iters() {
        let spec = StrategySpec::Adaptive {
            p_init: 4,
            warmup_iters: 0,
            ks_frac: 0.25,
            low: 0.7,
            high: 1.3,
        };
        let mut c = build(&spec, &Ctx { total_iters: 400 }).unwrap();
        // K_s = 0.25·400 = 100: sample C₂ = 2.0 for k < 100, then feed
        // tiny variance so the period must grow once adaptation starts
        let mut syncs = 0;
        for k in 0..400 {
            if c.should_sync(k) {
                let s_k = if k < 100 { 0.2 } else { 0.001 };
                c.on_sync(k, s_k, 0.1);
                syncs += 1;
            }
        }
        assert!(c.current_period() > 4, "period should grow after K_s");
        assert!(syncs > 0);
    }

    #[test]
    fn newcomer_builders_map_specs_to_controllers() {
        let ctx = Ctx { total_iters: 1000 };
        let a = build(&StrategySpec::AdaComm { tau0: 12 }, &ctx).unwrap();
        assert_eq!(a.name(), "adacomm");
        assert_eq!(a.current_period(), 12);
        assert!(a.wants_loss());
        let p = build(&StrategySpec::PrSgd { period: 6 }, &ctx).unwrap();
        assert_eq!(p.name(), "constant", "PR-SGD restarts live in SyncStep");
        assert_eq!(p.current_period(), 6);
        assert!(!p.wants_loss());
        let d = build(&StrategySpec::DaSgd { period: 8, delay: 2 }, &ctx).unwrap();
        assert_eq!(d.name(), "constant", "DaSGD delay lives in SyncStep");
        assert_eq!(d.current_period(), 8);
    }

    #[test]
    fn custom_builder_replaces_builtin() {
        fn every_iter(_: &StrategySpec, _: &Ctx) -> Option<Box<dyn PeriodController>> {
            Some(Box::new(Constant::new(1)))
        }
        let mut r = Registry::with_defaults();
        r.register("adaptive", every_iter);
        let ctrl = r
            .build(&StrategySpec::default_of(Strategy::Adaptive), &Ctx { total_iters: 100 })
            .unwrap();
        assert_eq!(ctrl.name(), "constant");
        assert_eq!(ctrl.current_period(), 1);
    }
}
