//! QSGD gradient quantization (Alistarh et al. 2017) — the paper's
//! compression baseline (§IV, "QSGD with 8 bits per component").
//!
//! Rust mirror of the L1 Pallas quantizer kernel with the full wire
//! format: per-bucket f32 2-norm + one byte (sign ⊕ 7-bit level) per
//! component at s = 127 levels, or the generic `levels <= 255` path used
//! by the convergence experiments (level stored in a byte, sign packed
//! separately).  `encode`/`decode` round-trip exactly; `quantize_inplace`
//! is the hot-path fused quantize+dequantize used when only the
//! information loss matters (the netsim ledger charges wire bytes).
//!
//! In the coordinator this codec plugs into the synchronization pipeline
//! as a [`crate::coordinator::sync::GradTransform`] — the same hook
//! top-k sparsification uses — so QSGD is a stage composition, not a
//! special-cased branch.

use crate::util::rng::Rng;

/// Quantizer configuration.
#[derive(Debug, Clone, Copy)]
pub struct QsgdConfig {
    /// number of positive quantization levels s (8 bits -> 255 in the
    /// paper's accounting; we default to the same)
    pub levels: u32,
    pub bucket: usize,
}

impl Default for QsgdConfig {
    fn default() -> Self {
        QsgdConfig { levels: 255, bucket: 512 }
    }
}

impl QsgdConfig {
    /// Wire bytes the encoded form of a length-`n` vector occupies:
    /// one f32 norm per bucket + one level byte per component + packed
    /// sign bits.  (What [`Encoded::wire_bytes`] reports, without
    /// materializing an encoding — used by the ledger pricing.)
    pub fn wire_bytes(&self, n: usize) -> u64 {
        (n.div_ceil(self.bucket) * 4 + n + n.div_ceil(8)) as u64
    }
}

/// Encoded representation of one vector.
#[derive(Debug, Clone, Default)]
pub struct Encoded {
    pub len: usize,
    pub levels: u32,
    pub bucket: usize,
    /// per-bucket 2-norms
    pub norms: Vec<f32>,
    /// per-component quantization level (0..=levels)
    pub qs: Vec<u8>,
    /// per-component sign bits, packed
    pub signs: Vec<u8>,
}

impl Encoded {
    /// Bytes on the wire: norms (4B each) + one level byte per component
    /// + packed sign bits.
    pub fn wire_bytes(&self) -> u64 {
        (self.norms.len() * 4 + self.qs.len() + self.signs.len()) as u64
    }
}

fn bucket_norm(x: &[f32]) -> f32 {
    // 8-lane chunked sum of squares (shared with the tensor reductions)
    crate::tensor::sq_norm(x).sqrt() as f32
}

/// Compute every bucket's 2-norm into `norms` (cleared + resized).  The
/// buckets are independent, so this pre-pass runs across the
/// [`crate::tensor::par`] pool — disjoint writes, bit-identical at any
/// thread count — leaving the stochastic level pass as the single
/// sequential walk that owns the RNG draw order.
fn fill_norms(x: &[f32], bucket: usize, norms: &mut Vec<f32>) {
    let n = x.len();
    let nbuckets = n.div_ceil(bucket);
    norms.clear();
    norms.resize(nbuckets, 0.0);
    let out = crate::tensor::par::SendPtr(norms.as_mut_ptr());
    crate::tensor::par::for_indices(nbuckets, &|b| {
        let lo = b * bucket;
        let hi = (lo + bucket).min(n);
        // SAFETY: one write per bucket index; `norms` outlives the dispatch.
        unsafe { *out.0.add(b) = bucket_norm(&x[lo..hi]) };
    });
}

/// Reusable per-call buffers for the fused quantize path: call sites
/// that quantize every sync hold one of these (e.g. the coordinator's
/// QSGD transform) so the hot loop never reallocates.
#[derive(Debug, Default, Clone)]
pub struct QsgdScratch {
    norms: Vec<f32>,
}

/// Stochastically quantize `x` (QSGD): per bucket, level_i =
/// floor(|x_i|/norm * s + u_i) with u ~ U[0,1).
pub fn encode(x: &[f32], cfg: &QsgdConfig, rng: &mut Rng) -> Encoded {
    let mut out = Encoded {
        len: 0,
        levels: cfg.levels,
        bucket: cfg.bucket,
        norms: Vec::new(),
        qs: Vec::new(),
        signs: Vec::new(),
    };
    encode_into(x, cfg, rng, &mut out);
    out
}

/// [`encode`] into a reusable `Encoded` — no allocations after warmup.
/// Sites that encode every sync keep one `Encoded` alive instead of
/// reallocating `norms`/`qs`/`signs` per call.  Draws exactly one RNG
/// value per component of each nonzero-norm bucket, in index order
/// (the same stream [`quantize_inplace`] consumes).
pub fn encode_into(x: &[f32], cfg: &QsgdConfig, rng: &mut Rng, out: &mut Encoded) {
    assert!(cfg.levels >= 1 && cfg.levels <= 255);
    let n = x.len();
    out.len = n;
    out.levels = cfg.levels;
    out.bucket = cfg.bucket;
    fill_norms(x, cfg.bucket, &mut out.norms);
    out.qs.clear();
    out.qs.resize(n, 0);
    out.signs.clear();
    out.signs.resize(n.div_ceil(8), 0);
    let s = cfg.levels as f32;
    for (b, &norm) in out.norms.iter().enumerate() {
        if norm <= 0.0 {
            continue;
        }
        let lo = b * cfg.bucket;
        let hi = (lo + cfg.bucket).min(n);
        for i in lo..hi {
            let v = x[i];
            if v < 0.0 {
                out.signs[i / 8] |= 1 << (i % 8);
            }
            let scaled = v.abs() / norm * s;
            let level = (scaled + rng.f32()).floor();
            out.qs[i] = level.min(s) as u8; // clamp: |x| <= norm so level <= s
        }
    }
}

/// Decode into `out` (len must match).
pub fn decode(e: &Encoded, out: &mut [f32]) {
    assert_eq!(out.len(), e.len);
    let s = e.levels as f32;
    for (b, &norm) in e.norms.iter().enumerate() {
        let lo = b * e.bucket;
        let hi = (lo + e.bucket).min(e.len);
        for i in lo..hi {
            let mut v = norm * e.qs[i] as f32 / s;
            if e.signs[i / 8] >> (i % 8) & 1 == 1 {
                v = -v;
            }
            out[i] = v;
        }
    }
}

/// Fused quantize+dequantize (hot path for convergence experiments).
/// Returns the wire bytes the encoded form would occupy.
pub fn quantize_inplace(x: &mut [f32], cfg: &QsgdConfig, rng: &mut Rng) -> u64 {
    quantize_inplace_with(x, cfg, rng, &mut QsgdScratch::default())
}

/// [`quantize_inplace`] with caller-held scratch: the bucket-norm
/// buffer is reused across calls, so per-sync quantization allocates
/// nothing.  RNG draw order is identical to [`quantize_inplace`] and
/// [`encode`] (norms are a deterministic pre-pass; the stochastic walk
/// stays sequential).
pub fn quantize_inplace_with(
    x: &mut [f32],
    cfg: &QsgdConfig,
    rng: &mut Rng,
    scratch: &mut QsgdScratch,
) -> u64 {
    let n = x.len();
    let s = cfg.levels as f32;
    fill_norms(x, cfg.bucket, &mut scratch.norms);
    for (b, &norm) in scratch.norms.iter().enumerate() {
        if norm <= 0.0 {
            continue;
        }
        let lo = b * cfg.bucket;
        let hi = (lo + cfg.bucket).min(n);
        let inv = norm / s;
        for v in &mut x[lo..hi] {
            let scaled = v.abs() / norm * s;
            let level = (scaled + rng.f32()).floor().min(s);
            *v = v.signum() * level * inv;
        }
    }
    cfg.wire_bytes(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn roundtrip_error_bounded() {
        // per-component error <= norm/s
        forall("qsgd-error-bound", 32, |g| {
            let x = g.vec_normal(1..2000, 1.0);
            let cfg = QsgdConfig { levels: 255, bucket: 512 };
            let mut rng = Rng::new(g.seed, 99);
            let e = encode(&x, &cfg, &mut rng);
            let mut out = vec![0.0; x.len()];
            decode(&e, &mut out);
            for b in 0..x.len().div_ceil(cfg.bucket) {
                let lo = b * cfg.bucket;
                let hi = (lo + cfg.bucket).min(x.len());
                let norm = bucket_norm(&x[lo..hi]);
                let bound = norm / cfg.levels as f32 + 1e-6;
                for i in lo..hi {
                    assert!(
                        (out[i] - x[i]).abs() <= bound,
                        "i={i} err={} bound={bound}",
                        (out[i] - x[i]).abs()
                    );
                }
            }
        });
    }

    #[test]
    fn encode_decode_matches_inplace() {
        forall("qsgd-enc-vs-inplace", 16, |g| {
            let x = g.vec_normal(10..3000, 2.0);
            let cfg = QsgdConfig { levels: 15, bucket: 128 };
            let mut r1 = Rng::new(g.seed, 5);
            let mut r2 = Rng::new(g.seed, 5);
            let e = encode(&x, &cfg, &mut r1);
            let mut dec = vec![0.0; x.len()];
            decode(&e, &mut dec);
            let mut inp = x.clone();
            let bytes = quantize_inplace(&mut inp, &cfg, &mut r2);
            assert_eq!(bytes, e.wire_bytes());
            for i in 0..x.len() {
                assert!((dec[i] - inp[i]).abs() < 1e-6, "i={i}: {} vs {}", dec[i], inp[i]);
            }
        });
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut gen_rng = Rng::new(1, 0);
        let mut x = vec![0.0f32; 256];
        gen_rng.fill_normal(&mut x, 1.0);
        let cfg = QsgdConfig { levels: 255, bucket: 256 };
        let mut acc = vec![0.0f64; 256];
        let trials = 400;
        let mut rng = Rng::new(7, 7);
        for _ in 0..trials {
            let mut q = x.clone();
            quantize_inplace(&mut q, &cfg, &mut rng);
            for i in 0..256 {
                acc[i] += q[i] as f64;
            }
        }
        let norm = bucket_norm(&x);
        let step = norm / 255.0;
        for i in 0..256 {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - x[i] as f64).abs() < 4.0 * step as f64 / (trials as f64).sqrt() + 1e-3,
                "i={i} mean={mean} x={}",
                x[i]
            );
        }
    }

    #[test]
    fn config_wire_bytes_matches_encoded() {
        let cfg = QsgdConfig { levels: 63, bucket: 200 };
        for n in [1usize, 199, 200, 201, 4096, 10_001] {
            let x = vec![1.0f32; n];
            let mut rng = Rng::new(3, 3);
            let e = encode(&x, &cfg, &mut rng);
            assert_eq!(cfg.wire_bytes(n), e.wire_bytes(), "n={n}");
        }
    }

    #[test]
    fn wire_bytes_quarter_of_f32() {
        // paper: 8-bit QSGD sends ~1/4 the data of 32-bit gradients
        let x = vec![1.0f32; 1 << 20];
        let cfg = QsgdConfig::default();
        let mut rng = Rng::new(0, 0);
        let e = encode(&x, &cfg, &mut rng);
        let full = (x.len() * 4) as f64;
        let ratio = full / e.wire_bytes() as f64;
        assert!(ratio > 3.0 && ratio < 4.0, "compression ratio {ratio}");
    }

    #[test]
    fn zero_vector_roundtrips() {
        let x = vec![0.0f32; 100];
        let cfg = QsgdConfig { levels: 3, bucket: 32 };
        let mut rng = Rng::new(0, 1);
        let e = encode(&x, &cfg, &mut rng);
        let mut out = vec![9.0; 100];
        decode(&e, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn encode_into_reuses_buffers_and_matches_encode() {
        let cfg = QsgdConfig { levels: 31, bucket: 64 };
        let mut out = Encoded {
            len: 0,
            levels: 0,
            bucket: 0,
            norms: Vec::new(),
            qs: Vec::new(),
            signs: Vec::new(),
        };
        // reuse across calls of different lengths, incl. shrinking
        for (round, n) in [1000usize, 130, 1000, 7].into_iter().enumerate() {
            let mut x = vec![0.0f32; n];
            Rng::new(40 + round as u64, 1).fill_normal(&mut x, 1.0);
            let mut r1 = Rng::new(11, round as u64);
            let mut r2 = r1.clone();
            encode_into(&x, &cfg, &mut r1, &mut out);
            let fresh = encode(&x, &cfg, &mut r2);
            assert_eq!(out.len, fresh.len);
            assert_eq!(out.norms, fresh.norms);
            assert_eq!(out.qs, fresh.qs);
            assert_eq!(out.signs, fresh.signs);
            assert_eq!(out.wire_bytes(), fresh.wire_bytes());
        }
    }

    #[test]
    fn scratch_variant_matches_plain_inplace() {
        let cfg = QsgdConfig { levels: 255, bucket: 512 };
        let mut scratch = QsgdScratch::default();
        for n in [5usize, 600, 5000] {
            let mut x = vec![0.0f32; n];
            Rng::new(n as u64, 2).fill_normal(&mut x, 1.0);
            let mut a = x.clone();
            let mut b = x;
            let bytes_a = quantize_inplace(&mut a, &cfg, &mut Rng::new(3, 3));
            let bytes_b = quantize_inplace_with(&mut b, &cfg, &mut Rng::new(3, 3), &mut scratch);
            assert_eq!(bytes_a, bytes_b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn quantization_bit_identical_across_thread_counts() {
        // the norms pre-pass is parallel; the quantized output (and the
        // RNG stream it consumes) must not depend on the thread count
        let _guard = crate::tensor::par::test_serial();
        let cfg = QsgdConfig::default();
        let n = 300_000;
        let mut x = vec![0.0f32; n];
        Rng::new(8, 8).fill_normal(&mut x, 1.0);
        crate::tensor::par::set_threads(1);
        let mut reference = x.clone();
        quantize_inplace(&mut reference, &cfg, &mut Rng::new(9, 9));
        for t in [2usize, 7] {
            crate::tensor::par::set_threads(t);
            let mut q = x.clone();
            quantize_inplace(&mut q, &cfg, &mut Rng::new(9, 9));
            assert_eq!(q, reference, "threads={t}");
        }
        crate::tensor::par::set_threads(0);
    }

    #[test]
    fn max_magnitude_maps_to_top_level() {
        // single nonzero element: |x| == norm -> level == s exactly
        let mut x = vec![0.0f32; 8];
        x[3] = -2.5;
        let cfg = QsgdConfig { levels: 7, bucket: 8 };
        let mut rng = Rng::new(2, 2);
        let e = encode(&x, &cfg, &mut rng);
        assert_eq!(e.qs[3], 7);
        let mut out = vec![0.0; 8];
        decode(&e, &mut out);
        assert!((out[3] + 2.5).abs() < 1e-6);
    }
}
