//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! training hot path.
//!
//! This is the rust half of the AOT bridge (see `python/compile/aot.py`):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`.  One [`HloEngine`] per worker thread — the `xla` crate's
//! handles hold raw pointers and are not `Send`, so engines are
//! constructed *inside* their thread by the coordinator's engine factory.
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained.
//!
//! The PJRT execution path sits behind the `pjrt` cargo feature (the
//! `xla` crate must be vendored to enable it); without the feature the
//! manifest still parses and [`HloEngine::load`] returns an actionable
//! error so the native backend — and every test on it — works on a
//! plain offline checkout.

use crate::data::Batch;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json` entry for one model preset.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    /// "class" or "lm"
    pub kind: String,
    pub param_count: usize,
    pub momentum: f32,
    pub qsgd_levels: u32,
    pub batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub classes: usize,
    pub vocab: usize,
    pub seq: usize,
    pub files: BTreeMap<String, String>,
}

/// The artifact directory + its manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.get("shape")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .ok_or_else(|| anyhow!("missing shape"))
}

impl Manifest {
    /// Cached [`Manifest::load`]: one parse per artifacts directory per
    /// process, so campaign sweeps over HLO models share the manifest
    /// (and its error path stays uncached — a missing directory keeps
    /// erroring with the actionable message).
    pub fn load_cached(dir: impl AsRef<Path>) -> Result<std::sync::Arc<Manifest>> {
        use crate::util::memo;
        use std::sync::OnceLock;
        static CACHE: memo::Cache<PathBuf, Manifest> = OnceLock::new();
        let key = dir.as_ref().to_path_buf();
        memo::get_or_try_build(&CACHE, key.clone(), || Self::load(&key))
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        if root.get("hlo").and_then(Json::as_str) != Some("text") {
            bail!("manifest {}: expected hlo=\"text\"", path.display());
        }
        let mut models = BTreeMap::new();
        let model_obj = root
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?;
        for (name, m) in model_obj {
            let files = m
                .get("files")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("model {name}: missing files"))?
                .iter()
                .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
                .collect();
            let spec = ModelSpec {
                name: name.clone(),
                kind: m
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model {name}: missing kind"))?
                    .to_string(),
                param_count: m
                    .get("param_count")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("model {name}: missing param_count"))?,
                momentum: m.get("momentum").and_then(Json::as_f64).unwrap_or(0.9) as f32,
                qsgd_levels: m.get("qsgd_levels").and_then(Json::as_usize).unwrap_or(255) as u32,
                batch: m.get("batch").and_then(Json::as_usize).unwrap_or(0),
                x_shape: shape_of(m.get("x").ok_or_else(|| anyhow!("model {name}: missing x"))?)?,
                y_shape: shape_of(m.get("y").ok_or_else(|| anyhow!("model {name}: missing y"))?)?,
                classes: m.get("classes").and_then(Json::as_usize).unwrap_or(0),
                vocab: m.get("vocab").and_then(Json::as_usize).unwrap_or(0),
                seq: m.get("seq").and_then(Json::as_usize).unwrap_or(0),
                files,
            };
            models.insert(name.clone(), spec);
        }
        Ok(Manifest { dir, models })
    }

    pub fn get(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model {name:?} not in manifest (have: {:?})", self.models.keys())
        })
    }
}

/// Which executables to compile (compilation is per-thread; skip what a
/// mode doesn't need).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineFns {
    pub step: bool,
    pub grad_apply: bool,
    pub eval: bool,
    pub sq_dev: bool,
    pub qsgd: bool,
}

impl Default for EngineFns {
    fn default() -> Self {
        EngineFns { step: true, grad_apply: false, eval: true, sq_dev: false, qsgd: false }
    }
}

impl EngineFns {
    pub fn all() -> Self {
        EngineFns { step: true, grad_apply: true, eval: true, sq_dev: true, qsgd: true }
    }
}

/// A compiled model on a per-thread PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct HloEngine {
    pub spec: ModelSpec,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    step: Option<xla::PjRtLoadedExecutable>,
    grad: Option<xla::PjRtLoadedExecutable>,
    apply: Option<xla::PjRtLoadedExecutable>,
    eval: Option<xla::PjRtLoadedExecutable>,
    init: xla::PjRtLoadedExecutable,
    sq_dev: Option<xla::PjRtLoadedExecutable>,
    qsgd: Option<xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
fn compile_one(
    client: &xla::PjRtClient,
    dir: &Path,
    file: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(file);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
}

#[cfg(feature = "pjrt")]
fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e:?}"))?)
}

#[cfg(feature = "pjrt")]
fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e:?}"))?)
}

#[cfg(feature = "pjrt")]
impl HloEngine {
    /// Load + compile the selected functions for `model` from `manifest`.
    pub fn load(manifest: &Manifest, model: &str, fns: EngineFns) -> Result<HloEngine> {
        let spec = manifest.get(model)?.clone();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let dir = &manifest.dir;
        let file = |key: &str| -> Result<&str> {
            spec.files
                .get(key)
                .map(String::as_str)
                .ok_or_else(|| anyhow!("model {model}: no {key} artifact"))
        };
        let maybe = |on: bool, key: &str| -> Result<Option<xla::PjRtLoadedExecutable>> {
            if on {
                Ok(Some(compile_one(&client, dir, file(key)?)?))
            } else {
                Ok(None)
            }
        };
        let init = compile_one(&client, dir, file("init")?)?;
        let step = maybe(fns.step, "step")?;
        let grad = maybe(fns.grad_apply, "grad")?;
        let apply = maybe(fns.grad_apply, "apply")?;
        let eval = maybe(fns.eval, "eval")?;
        let sq_dev = maybe(fns.sq_dev, "sq_dev")?;
        let qsgd = maybe(fns.qsgd, "qsgd")?;
        Ok(HloEngine { spec, client, step, grad, apply, eval, init, sq_dev, qsgd })
    }

    pub fn n_params(&self) -> usize {
        self.spec.param_count
    }

    fn run(
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let mut lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        lit.decompose_tuple().map_err(|e| anyhow!("decompose: {e:?}"))
    }

    fn batch_literals(&self, batch: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        match (batch, self.spec.kind.as_str()) {
            (Batch::Class { x, y, .. }, "class") => {
                Ok((lit_f32(x, &self.spec.x_shape)?, lit_i32(y, &self.spec.y_shape)?))
            }
            (Batch::Lm { x, y, .. }, "lm") => {
                Ok((lit_i32(x, &self.spec.x_shape)?, lit_i32(y, &self.spec.y_shape)?))
            }
            (b, k) => bail!("batch kind mismatch: model is {k:?}, batch is {b:?}"),
        }
    }

    /// init(seed) -> w0
    pub fn init(&self, seed: i32) -> Result<Vec<f32>> {
        let outs = Self::run(&self.init, &[xla::Literal::scalar(seed)])?;
        let w = outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        if w.len() != self.spec.param_count {
            bail!("init returned {} params, manifest says {}", w.len(), self.spec.param_count);
        }
        Ok(w)
    }

    /// Fused local step: (w, m) updated in place; returns loss.
    pub fn step(&self, w: &mut [f32], m: &mut [f32], batch: &Batch, lr: f32) -> Result<f32> {
        let exe = self.step.as_ref().ok_or_else(|| anyhow!("step not compiled"))?;
        let (xl, yl) = self.batch_literals(batch)?;
        let p = self.spec.param_count;
        let args = [
            lit_f32(w, &[p])?,
            lit_f32(m, &[p])?,
            xl,
            yl,
            xla::Literal::scalar(lr),
        ];
        let outs = Self::run(exe, &args)?;
        outs[0].copy_raw_to::<f32>(w).map_err(|e| anyhow!("{e:?}"))?;
        outs[1].copy_raw_to::<f32>(m).map_err(|e| anyhow!("{e:?}"))?;
        let loss = outs[2].get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(loss)
    }

    /// grad(w, batch) -> (g into `g`, loss)
    pub fn grad(&self, w: &[f32], batch: &Batch, g: &mut [f32]) -> Result<f32> {
        let exe = self.grad.as_ref().ok_or_else(|| anyhow!("grad not compiled"))?;
        let (xl, yl) = self.batch_literals(batch)?;
        let p = self.spec.param_count;
        let outs = Self::run(exe, &[lit_f32(w, &[p])?, xl, yl])?;
        outs[0].copy_raw_to::<f32>(g).map_err(|e| anyhow!("{e:?}"))?;
        let loss = outs[1].get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(loss)
    }

    /// apply(w, m, g, lr): fused momentum update (the L1 Pallas kernel).
    pub fn apply(&self, w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32) -> Result<()> {
        let exe = self.apply.as_ref().ok_or_else(|| anyhow!("apply not compiled"))?;
        let p = self.spec.param_count;
        let args = [lit_f32(w, &[p])?, lit_f32(m, &[p])?, lit_f32(g, &[p])?, xla::Literal::scalar(lr)];
        let outs = Self::run(exe, &args)?;
        outs[0].copy_raw_to::<f32>(w).map_err(|e| anyhow!("{e:?}"))?;
        outs[1].copy_raw_to::<f32>(m).map_err(|e| anyhow!("{e:?}"))?;
        Ok(())
    }

    /// eval(w, batch) -> (loss, accuracy)
    pub fn eval(&self, w: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let exe = self.eval.as_ref().ok_or_else(|| anyhow!("eval not compiled"))?;
        let (xl, yl) = self.batch_literals(batch)?;
        let p = self.spec.param_count;
        let outs = Self::run(exe, &[lit_f32(w, &[p])?, xl, yl])?;
        let loss = outs[0].get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let acc = outs[1].get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((loss, acc))
    }

    /// sq_dev(a, b) -> ||a-b||^2 via the L1 Pallas reduction kernel.
    pub fn sq_dev(&self, a: &[f32], b: &[f32]) -> Result<f64> {
        let exe = self.sq_dev.as_ref().ok_or_else(|| anyhow!("sq_dev not compiled"))?;
        let p = self.spec.param_count;
        let outs = Self::run(exe, &[lit_f32(a, &[p])?, lit_f32(b, &[p])?])?;
        Ok(outs[0].get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))? as f64)
    }

    /// qsgd(g, u) -> quantize-dequantized g (the L1 Pallas quantizer).
    pub fn qsgd(&self, g: &mut [f32], u: &[f32]) -> Result<()> {
        let exe = self.qsgd.as_ref().ok_or_else(|| anyhow!("qsgd not compiled"))?;
        let p = self.spec.param_count;
        let outs = Self::run(exe, &[lit_f32(g, &[p])?, lit_f32(u, &[p])?])?;
        outs[0].copy_raw_to::<f32>(g).map_err(|e| anyhow!("{e:?}"))?;
        Ok(())
    }
}

/// Stub for builds without the `pjrt` feature: the manifest still
/// parses (so `adpsgd models`, artifact validation, and the artifact
/// tests' skip logic all work), but loading an engine reports that the
/// execution path is compiled out.  Instances never exist, so the
/// per-op methods are unreachable and simply mirror the real signatures.
#[cfg(not(feature = "pjrt"))]
pub struct HloEngine {
    pub spec: ModelSpec,
}

#[cfg(not(feature = "pjrt"))]
impl HloEngine {
    pub fn load(manifest: &Manifest, model: &str, _fns: EngineFns) -> Result<HloEngine> {
        let _ = manifest.get(model)?;
        bail!(
            "model {model}: this build has no PJRT runtime (enable the `pjrt` \
             cargo feature with a vendored `xla` crate, or use the native backend)"
        )
    }

    pub fn n_params(&self) -> usize {
        self.spec.param_count
    }

    pub fn init(&self, _seed: i32) -> Result<Vec<f32>> {
        bail!("pjrt feature disabled")
    }

    pub fn step(&self, _w: &mut [f32], _m: &mut [f32], _batch: &Batch, _lr: f32) -> Result<f32> {
        bail!("pjrt feature disabled")
    }

    pub fn grad(&self, _w: &[f32], _batch: &Batch, _g: &mut [f32]) -> Result<f32> {
        bail!("pjrt feature disabled")
    }

    pub fn apply(&self, _w: &mut [f32], _m: &mut [f32], _g: &[f32], _lr: f32) -> Result<()> {
        bail!("pjrt feature disabled")
    }

    pub fn eval(&self, _w: &[f32], _batch: &Batch) -> Result<(f32, f32)> {
        bail!("pjrt feature disabled")
    }

    pub fn sq_dev(&self, _a: &[f32], _b: &[f32]) -> Result<f64> {
        bail!("pjrt feature disabled")
    }

    pub fn qsgd(&self, _g: &mut [f32], _u: &[f32]) -> Result<()> {
        bail!("pjrt feature disabled")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_shapes() {
        let tmp = std::env::temp_dir().join(format!("adpsgd_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"format":1,"hlo":"text","models":{"m1":{
                "kind":"class","param_count":10,"momentum":0.9,"qsgd_levels":255,
                "batch":4,"classes":3,"input_dim":5,
                "x":{"shape":[4,5],"dtype":"float32"},
                "y":{"shape":[4],"dtype":"int32"},
                "files":{"init":"m1.init.hlo.txt"},
                "args":{}}}}"#,
        )
        .unwrap();
        let man = Manifest::load(&tmp).unwrap();
        let spec = man.get("m1").unwrap();
        assert_eq!(spec.param_count, 10);
        assert_eq!(spec.x_shape, vec![4, 5]);
        assert_eq!(spec.kind, "class");
        assert!(man.get("nope").is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load("/nonexistent/path").unwrap_err().to_string();
        assert!(err.contains("manifest.json"), "{err}");
    }
}
