//! Top-k gradient sparsification — the *other* compression family the
//! paper's §VI discusses (Strom [12]; Aji & Heafield [53]; Lin et al.
//! "Deep Gradient Compression" [52]).
//!
//! Each node transmits only the k largest-magnitude gradient components
//! (index + value); the untransmitted remainder accumulates locally in a
//! *residual* and is added to the next step's gradient ("error
//! feedback" — without it top-k provably stalls).  Like QSGD it saves
//! bandwidth but not latency, and it cannot ride a summing allreduce, so
//! the netsim charges the PS-style exchange.
//!
//! This gives the evaluation a second compression baseline alongside
//! QSGD: ADPSGD's claim is against the whole compression family, not one
//! member.
//!
//! Like QSGD, top-k enters the coordinator through the synchronization
//! pipeline's [`crate::coordinator::sync::GradTransform`] hook (the
//! residual state lives in the transform, one per node).

/// Sparsifier configuration.
#[derive(Debug, Clone, Copy)]
pub struct TopKConfig {
    /// fraction of components kept (paper-family defaults: 0.01–0.1)
    pub keep_frac: f64,
}

impl Default for TopKConfig {
    fn default() -> Self {
        TopKConfig { keep_frac: 0.03125 } // 1/32: 4B value + 4B index per kept
    }
}

impl TopKConfig {
    pub fn k_for(&self, n: usize) -> usize {
        ((n as f64 * self.keep_frac).ceil() as usize).clamp(1, n)
    }

    /// Bytes on the wire for a vector of length `n`: (index + value) per
    /// kept component.
    pub fn wire_bytes(&self, n: usize) -> u64 {
        (self.k_for(n) * 8) as u64
    }
}

/// Error-feedback state: the accumulated untransmitted remainder.
#[derive(Debug, Clone)]
pub struct Residual {
    pub r: Vec<f32>,
}

impl Residual {
    pub fn new(n: usize) -> Self {
        Residual { r: vec![0.0; n] }
    }
}

/// Threshold of the k-th largest |x| via quickselect on a scratch copy
/// (O(n) average; avoids a full sort of multi-million-element gradients).
pub fn kth_magnitude(x: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= x.len());
    let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    let idx = mags.len() - k; // k-th largest = (n-k)-th smallest
    let (_, kth, _) = mags.select_nth_unstable_by(idx, f32::total_cmp);
    *kth
}

/// Sparsify `g` in place with error feedback:
/// 1. `g += residual`
/// 2. keep the k largest-|.| components of the sum, zero the rest
/// 3. `residual = dropped components`
///
/// Returns the wire bytes of the transmitted sparse vector.  Ties at the
/// threshold are broken by index order (deterministic), keeping exactly
/// k components.
pub fn sparsify_inplace(g: &mut [f32], res: &mut Residual, cfg: &TopKConfig) -> u64 {
    let n = g.len();
    assert_eq!(res.r.len(), n);
    let k = cfg.k_for(n);
    for (gi, ri) in g.iter_mut().zip(res.r.iter()) {
        *gi += *ri;
    }
    let thr = kth_magnitude(g, k);
    // strictly-greater components always ship (there are < k of them);
    // boundary ties fill the remaining budget in index order
    let greater = g.iter().filter(|v| v.abs() > thr).count();
    let mut tie_budget = k - greater;
    for (gi, ri) in g.iter_mut().zip(res.r.iter_mut()) {
        let mag = gi.abs();
        let keep = if mag > thr {
            true
        } else if mag == thr && tie_budget > 0 {
            tie_budget -= 1;
            true
        } else {
            false
        };
        if keep {
            *ri = 0.0;
        } else {
            *ri = *gi;
            *gi = 0.0;
        }
    }
    cfg.wire_bytes(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        Rng::new(seed, 0).fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn kth_magnitude_matches_sort() {
        for seed in 0..8 {
            let x = randvec(257, seed);
            let mut sorted: Vec<f32> = x.iter().map(|v| v.abs()).collect();
            sorted.sort_by(f32::total_cmp);
            for k in [1usize, 2, 17, 128, 257] {
                let got = kth_magnitude(&x, k);
                let want = sorted[sorted.len() - k];
                assert_eq!(got, want, "seed {seed} k {k}");
            }
        }
    }

    #[test]
    fn sparsify_keeps_exactly_k() {
        let cfg = TopKConfig { keep_frac: 0.1 };
        let mut g = randvec(1000, 3);
        let mut res = Residual::new(1000);
        sparsify_inplace(&mut g, &mut res, &cfg);
        let nz = g.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nz, cfg.k_for(1000));
    }

    #[test]
    fn kept_plus_residual_is_lossless() {
        // g_orig + r_old == g_sparse + r_new  (error feedback conserves mass)
        let cfg = TopKConfig { keep_frac: 0.05 };
        let g0 = randvec(512, 9);
        let mut g = g0.clone();
        let mut res = Residual::new(512);
        res.r.copy_from_slice(&randvec(512, 10));
        let r0 = res.r.clone();
        sparsify_inplace(&mut g, &mut res, &cfg);
        for i in 0..512 {
            let total_before = g0[i] + r0[i];
            let total_after = g[i] + res.r[i];
            assert!(
                (total_before - total_after).abs() < 1e-6,
                "mass lost at {i}: {total_before} vs {total_after}"
            );
        }
    }

    #[test]
    fn kept_components_are_the_largest() {
        let cfg = TopKConfig { keep_frac: 0.02 };
        let mut g = randvec(4096, 21);
        let mut res = Residual::new(4096);
        let summed = g.clone();
        sparsify_inplace(&mut g, &mut res, &cfg);
        let min_kept =
            g.iter().filter(|v| **v != 0.0).map(|v| v.abs()).fold(f32::INFINITY, f32::min);
        let max_dropped = summed
            .iter()
            .zip(g.iter())
            .filter(|(_, gi)| **gi == 0.0)
            .map(|(s, _)| s.abs())
            .fold(0.0f32, f32::max);
        assert!(
            min_kept >= max_dropped,
            "kept {min_kept} must dominate dropped {max_dropped}"
        );
    }

    #[test]
    fn residual_accumulates_small_components() {
        // a component too small to win top-k while big gradients flow
        // still gets through once they subside — the error-feedback
        // guarantee (without the residual it would be lost forever)
        let cfg = TopKConfig { keep_frac: 0.25 }; // k = 2 of 8
        let n = 8;
        let mut res = Residual::new(n);
        // phase 1: indices 0,1 dominate; index 7 trickles 0.01/step
        for _ in 0..30 {
            let mut g: Vec<f32> =
                (0..n).map(|i| if i < 2 { 1.0 } else if i == 7 { 0.01 } else { 0.0 }).collect();
            sparsify_inplace(&mut g, &mut res, &cfg);
            assert_eq!(g[7], 0.0, "small component must lose while big ones flow");
        }
        assert!((res.r[7] - 0.3).abs() < 1e-5, "residual accumulated: {}", res.r[7]);
        // phase 2: gradients subside; the accumulated residual ships
        let mut g = vec![0.0f32; n];
        sparsify_inplace(&mut g, &mut res, &cfg);
        assert!(
            (g[7] - 0.3).abs() < 1e-5,
            "residual must flush the small component: {}",
            g[7]
        );
        assert_eq!(res.r[7], 0.0);
    }

    #[test]
    fn wire_bytes_formula() {
        let cfg = TopKConfig { keep_frac: 0.01 };
        assert_eq!(cfg.wire_bytes(10_000), 100 * 8);
        assert_eq!(cfg.k_for(10), 1); // ceil + clamp
        let tiny = TopKConfig { keep_frac: 1e-9 };
        assert_eq!(tiny.k_for(5), 1, "at least one component always ships");
    }
}
