//! Flat `f32` vector math — the coordinator's parameter algebra.
//!
//! Everything the paper's Algorithms 1/2 do outside the model step is
//! elementwise vector work on flat parameter vectors: averaging,
//! momentum updates (for the pure-rust workload path), the `S_k`
//! squared-deviation statistic, norms.  Inner kernels are written as
//! explicit 8-lane (`LANES`) loops so they vectorize unconditionally,
//! and large inputs are partitioned across the [`par`] thread pool on
//! [`RCHUNK`] boundaries.  Reductions keep a fixed summation order (f32
//! lanes within a chunk, f64 chunk totals folded in chunk order), so
//! every result is **bit-identical at any thread count** — see the
//! property tests in [`par`].

pub mod par;

/// Reduction chunk: f32 math inside a chunk (8 independent lanes so
/// LLVM vectorizes the reduction), f64 accumulation across chunks (so
/// precision matches a plain f64 loop to ~1e-6 relative at 100M+
/// elements).  4096 f32 = 16 KiB per input — L1-resident.  Also the
/// unit of work the [`par`] pool claims, which is what keeps the
/// summation order independent of the thread count.
pub(crate) const RCHUNK: usize = 4096;
pub(crate) const LANES: usize = 8;

#[inline]
fn lanes_total(lanes: [f32; LANES]) -> f64 {
    // fixed order: deterministic regardless of chunk boundaries
    let mut t = 0.0f64;
    for l in lanes {
        t += l as f64;
    }
    t
}

/// y += a * x over one range (8-lane inner loop).
#[inline]
fn axpy_range(y: &mut [f32], a: f32, x: &[f32]) {
    for (yv, xv) in y.chunks_exact_mut(LANES).zip(x.chunks_exact(LANES)) {
        for l in 0..LANES {
            yv[l] += a * xv[l];
        }
    }
    let n = y.len();
    let rem = n - n % LANES;
    for i in rem..n {
        y[i] += a * x[i];
    }
}

/// y += a * x  (axpy).  Elementwise, so any partition is bit-identical.
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let yp = par::SendPtr(y.as_mut_ptr());
    par::for_ranges(y.len(), &|lo, hi| {
        // SAFETY: ranges are disjoint; the slice outlives the dispatch.
        let yc = unsafe { std::slice::from_raw_parts_mut(yp.0.add(lo), hi - lo) };
        axpy_range(yc, a, &x[lo..hi]);
    });
}

#[inline]
fn scale_range(y: &mut [f32], a: f32) {
    for yv in y.chunks_exact_mut(LANES) {
        for l in 0..LANES {
            yv[l] *= a;
        }
    }
    let n = y.len();
    let rem = n - n % LANES;
    for i in rem..n {
        y[i] *= a;
    }
}

/// y = a * y.
pub fn scale(y: &mut [f32], a: f32) {
    let yp = par::SendPtr(y.as_mut_ptr());
    par::for_ranges(y.len(), &|lo, hi| {
        // SAFETY: disjoint ranges; slice outlives the dispatch.
        let yc = unsafe { std::slice::from_raw_parts_mut(yp.0.add(lo), hi - lo) };
        scale_range(yc, a);
    });
}

/// One-chunk dot partial: f32 lanes, f64 total (fixed order).
#[inline]
fn dot_chunk(ca: &[f32], cb: &[f32]) -> f64 {
    let mut lanes = [0.0f32; LANES];
    for (xa, xb) in ca.chunks_exact(LANES).zip(cb.chunks_exact(LANES)) {
        for l in 0..LANES {
            lanes[l] += xa[l] * xb[l];
        }
    }
    let rem = ca.len() - ca.len() % LANES;
    for i in rem..ca.len() {
        lanes[i - rem] += ca[i] * cb[i];
    }
    lanes_total(lanes)
}

/// Dot product: f32 lanes within chunks, f64 across chunks.
/// Deterministic (fixed summation order) at any thread count.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    par::reduce2(a, b, dot_chunk)
}

#[inline]
fn sq_norm_chunk(c: &[f32]) -> f64 {
    let mut lanes = [0.0f32; LANES];
    for xa in c.chunks_exact(LANES) {
        for l in 0..LANES {
            lanes[l] += xa[l] * xa[l];
        }
    }
    let rem = c.len() - c.len() % LANES;
    for i in rem..c.len() {
        lanes[i - rem] += c[i] * c[i];
    }
    lanes_total(lanes)
}

/// ||x||^2 (chunked-lane reduction; see [`dot`]).
pub fn sq_norm(x: &[f32]) -> f64 {
    par::reduce1(x, sq_norm_chunk)
}

#[inline]
fn sq_deviation_chunk(ca: &[f32], cb: &[f32]) -> f64 {
    let mut lanes = [0.0f32; LANES];
    for (xa, xb) in ca.chunks_exact(LANES).zip(cb.chunks_exact(LANES)) {
        for l in 0..LANES {
            let d = xa[l] - xb[l];
            lanes[l] += d * d;
        }
    }
    let rem = ca.len() - ca.len() % LANES;
    for i in rem..ca.len() {
        let d = ca[i] - cb[i];
        lanes[i - rem] += d * d;
    }
    lanes_total(lanes)
}

/// ||a - b||^2 — the per-node S_k term (paper eq. 16 / Alg. 2 line 11).
/// The coordinator calls this at every synchronization; chunked-lane
/// reduction (see [`dot`]) keeps it at memory bandwidth.
pub fn sq_deviation(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    par::reduce2(a, b, sq_deviation_chunk)
}

/// out = mean of rows (each `rows[i]` same length).  The averaging step
/// of Algorithm 1/2 line 10 when done leader-side.  Per-element the
/// arithmetic is `((row0 + row1) + ...) * inv` in fixed row order, so
/// any range partition is bit-identical to the serial loop.
pub fn mean_rows(rows: &[&[f32]], out: &mut [f32]) {
    let n = rows.len();
    assert!(n > 0);
    let inv = 1.0 / n as f32;
    let op = par::SendPtr(out.as_mut_ptr());
    par::for_ranges(out.len(), &|lo, hi| {
        // SAFETY: disjoint ranges; slice outlives the dispatch.
        let oc = unsafe { std::slice::from_raw_parts_mut(op.0.add(lo), hi - lo) };
        oc.copy_from_slice(&rows[0][lo..hi]);
        for row in &rows[1..] {
            debug_assert_eq!(row.len(), rows[0].len());
            axpy_range(oc, 1.0, &row[lo..hi]);
        }
        scale_range(oc, inv);
    });
}

/// Variance of model parameters among nodes (paper eq. 7):
/// `Var[W] = (1/n) Σ_i ||w_bar - w_i||^2`, with `w_bar` the row mean.
/// Returns (variance, w_bar in `scratch`).
pub fn param_variance(rows: &[&[f32]], scratch: &mut [f32]) -> f64 {
    mean_rows(rows, scratch);
    let mut acc = 0.0f64;
    for row in rows {
        acc += sq_deviation(scratch, row);
    }
    acc / rows.len() as f64
}

/// In-place elementwise add: y += x.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    axpy(y, 1.0, x);
}

#[inline]
fn momentum_range(w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    for ((wv, mv), gv) in w
        .chunks_exact_mut(LANES)
        .zip(m.chunks_exact_mut(LANES))
        .zip(g.chunks_exact(LANES))
    {
        for l in 0..LANES {
            mv[l] = mu * mv[l] + gv[l];
            wv[l] -= lr * mv[l];
        }
    }
    let n = w.len();
    let rem = n - n % LANES;
    for i in rem..n {
        m[i] = mu * m[i] + g[i];
        w[i] -= lr * m[i];
    }
}

/// Fused momentum-SGD update (rust mirror of the L1 Pallas kernel, used
/// by the pure-rust `workload` path):  m = mu*m + g;  w -= lr*m.
pub fn momentum_update(w: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    debug_assert_eq!(w.len(), m.len());
    debug_assert_eq!(w.len(), g.len());
    let wp = par::SendPtr(w.as_mut_ptr());
    let mp = par::SendPtr(m.as_mut_ptr());
    par::for_ranges(w.len(), &|lo, hi| {
        // SAFETY: disjoint ranges; both slices outlive the dispatch.
        let wc = unsafe { std::slice::from_raw_parts_mut(wp.0.add(lo), hi - lo) };
        let mc = unsafe { std::slice::from_raw_parts_mut(mp.0.add(lo), hi - lo) };
        momentum_range(wc, mc, &g[lo..hi], lr, mu);
    });
}

#[inline]
fn elastic_range(w: &mut [f32], pre: &[f32], alpha: f32) {
    for (wv, pv) in w.chunks_exact_mut(LANES).zip(pre.chunks_exact(LANES)) {
        for l in 0..LANES {
            wv[l] = pv[l] + alpha * (wv[l] - pv[l]);
        }
    }
    let n = w.len();
    let rem = n - n % LANES;
    for i in rem..n {
        w[i] = pre[i] + alpha * (w[i] - pre[i]);
    }
}

/// EASGD elastic pull (the paper's [57]): instead of adopting the mean,
/// each node moves a fraction α of the way toward it,
/// `w ← pre + α·(w − pre)`, where `w` currently holds the mean and
/// `pre` the node's pre-averaging parameters.  α = 1 is exactly CPSGD;
/// α = 0 ignores the sync entirely.  This is the elastic stage of the
/// coordinator's `SyncStep` pipeline.
pub fn elastic_pull(w: &mut [f32], pre: &[f32], alpha: f32) {
    debug_assert_eq!(w.len(), pre.len());
    let wp = par::SendPtr(w.as_mut_ptr());
    par::for_ranges(w.len(), &|lo, hi| {
        // SAFETY: disjoint ranges; slice outlives the dispatch.
        let wc = unsafe { std::slice::from_raw_parts_mut(wp.0.add(lo), hi - lo) };
        elastic_range(wc, &pre[lo..hi], alpha);
    });
}

/// max |a_i - b_i|, for test assertions.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn axpy_and_scale() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![10.5, 21.0, 31.5]);
    }

    #[test]
    fn dot_and_norms() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(sq_norm(&[3.0, 4.0]), 25.0);
        assert_eq!(sq_deviation(&[1.0, 1.0], &[0.0, 0.0]), 2.0);
    }

    #[test]
    fn mean_rows_basic() {
        let r1 = [1.0, 2.0];
        let r2 = [3.0, 6.0];
        let mut out = [0.0; 2];
        mean_rows(&[&r1, &r2], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn variance_zero_when_identical() {
        let r = [0.5f32; 16];
        let mut scratch = [0.0f32; 16];
        let v = param_variance(&[&r, &r, &r], &mut scratch);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn variance_known_value() {
        // rows 0 and 2: mean 1, each deviates by 1 -> Var = (1+1)/2 = 1 per dim
        let a = [0.0f32; 4];
        let b = [2.0f32; 4];
        let mut scratch = [0.0f32; 4];
        let v = param_variance(&[&a, &b], &mut scratch);
        assert_eq!(v, 4.0); // ||dev||^2 = 4 per row, averaged = 4
    }

    #[test]
    fn elastic_pull_endpoints_and_midpoint() {
        let pre = [1.0f32, 2.0, 3.0];
        // α = 1: adopt the mean unchanged (CPSGD)
        let mut w = [4.0f32, 6.0, 8.0];
        elastic_pull(&mut w, &pre, 1.0);
        assert_eq!(w, [4.0, 6.0, 8.0]);
        // α = 0: keep the local parameters
        let mut w = [4.0f32, 6.0, 8.0];
        elastic_pull(&mut w, &pre, 0.0);
        assert_eq!(w, [1.0, 2.0, 3.0]);
        // α = 0.5: halfway
        let mut w = [4.0f32, 6.0, 8.0];
        elastic_pull(&mut w, &pre, 0.5);
        assert_eq!(w, [2.5, 4.0, 5.5]);
    }

    #[test]
    fn momentum_update_matches_reference() {
        forall("momentum-vs-ref", 32, |g| {
            let n = g.usize_in(1..300);
            let w0 = g.vec_normal(n..n + 1, 1.0);
            let m0 = g.vec_normal(n..n + 1, 1.0);
            let grad = g.vec_normal(n..n + 1, 1.0);
            let (lr, mu) = (g.f32_in(0.001, 1.0), g.f32_in(0.0, 0.99));
            let mut w = w0.clone();
            let mut m = m0.clone();
            momentum_update(&mut w, &mut m, &grad, lr, mu);
            for i in 0..n {
                let m_ref = mu * m0[i] + grad[i];
                let w_ref = w0[i] - lr * m_ref;
                assert!((m[i] - m_ref).abs() < 1e-5);
                assert!((w[i] - w_ref).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn variance_invariant_under_common_shift() {
        forall("var-shift-invariant", 24, |g| {
            let n = g.usize_in(2..50);
            let k = g.usize_in(2..6);
            let rows: Vec<Vec<f32>> = (0..k).map(|_| g.vec_normal(n..n + 1, 1.0)).collect();
            let shift = g.f32_in(-5.0, 5.0);
            let shifted: Vec<Vec<f32>> =
                rows.iter().map(|r| r.iter().map(|x| x + shift).collect()).collect();
            let mut s1 = vec![0.0; n];
            let mut s2 = vec![0.0; n];
            let v1 = param_variance(&rows.iter().map(|r| r.as_slice()).collect::<Vec<_>>(), &mut s1);
            let v2 = param_variance(
                &shifted.iter().map(|r| r.as_slice()).collect::<Vec<_>>(),
                &mut s2,
            );
            assert!((v1 - v2).abs() < 1e-3 * (1.0 + v1.abs()), "{v1} vs {v2}");
        });
    }
}
