//! Work-partitioned parallel backend for the flat-vector kernels.
//!
//! A small owned thread pool splits flat parameter vectors on the same
//! [`RCHUNK`] boundaries the scalar kernels already reduce over, so
//! **every reduction keeps its fixed summation order**: each chunk's
//! f32-lane partial is computed (possibly on another thread) and the
//! f64 chunk totals are folded in chunk order on the calling thread.
//! The result is bit-identical to the serial path at any thread count —
//! `perf.threads = 1`, `= 4`, and `= 0` (auto) all produce the same
//! bytes, which is what lets the run cache and the campaign stable
//! summaries ignore the knob entirely.
//!
//! Design notes:
//! * One process-wide pool ([`set_threads`] adjusts how many workers
//!   participate; `0` = auto = all cores, `1` = run inline, exactly the
//!   pre-parallel behavior).  Helpers are spawned lazily on first use
//!   and park on a condvar between jobs.
//! * One parallel job at a time: a submitter that finds the pool busy
//!   (the coordinator runs one kernel per rank thread concurrently)
//!   simply runs its loop inline.  Results cannot differ — only
//!   wall-clock can — so composition with the rank-level parallelism is
//!   free of both deadlock and nondeterminism.
//! * Work is claimed chunk-by-chunk from an atomic counter, so ragged
//!   tails and slow cores balance without any static partitioning.
//! * Inputs below [`PAR_MIN`] never cross the pool: the dispatch
//!   overhead (~µs) would dominate sub-64KiB memory traffic.

use super::RCHUNK;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock, TryLockError};

/// Below this many elements a kernel always runs inline: the pool
/// wake-up costs more than the memory traffic it would split.
pub(crate) const PAR_MIN: usize = 4 * RCHUNK;

/// Requested worker count: 0 = auto (all cores), 1 = serial.
static REQUESTED: AtomicUsize = AtomicUsize::new(0);

/// Set the kernel thread count (the `perf.threads` config knob).
/// `0` = auto (one worker per core), `1` = serial.  Results are
/// bit-identical at any setting; only wall-clock changes, which is why
/// the run-cache digest excludes the knob.
pub fn set_threads(t: usize) {
    REQUESTED.store(t, Ordering::Relaxed);
}

/// The effective kernel thread count (resolving auto to core count).
pub fn threads() -> usize {
    match REQUESTED.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        t => t,
    }
}

/// A raw pointer that workers may write through at **disjoint** chunk
/// offsets.  Safety contract (caller's): every index is written by at
/// most one closure invocation, and the buffer outlives the dispatch
/// (guaranteed — [`Pool::run`] joins before returning).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

struct PoolState {
    epoch: u64,
    /// helpers participating in the current job (worker idx < width)
    width: usize,
    /// helpers still running the current job
    running: usize,
    panicked: bool,
    task: Option<&'static (dyn Fn() + Sync)>,
}

/// The owned thread pool: broadcast one job, caller participates, wait
/// for all helpers.  See module docs for the busy-means-inline rule.
struct Pool {
    m: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// one job at a time; contended submitters run inline instead
    gate: Mutex<()>,
    helpers: usize,
}

fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Pool {
    fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        static SPAWN: std::sync::Once = std::sync::Once::new();
        let pool = POOL.get_or_init(|| {
            let avail =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            // at least 7 helpers even on small machines, so thread-count
            // sweeps (tests, perf.threads > cores) are exercised for real
            Pool {
                m: Mutex::new(PoolState {
                    epoch: 0,
                    width: 0,
                    running: 0,
                    panicked: false,
                    task: None,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
                gate: Mutex::new(()),
                helpers: avail.max(8) - 1,
            }
        });
        SPAWN.call_once(|| {
            let p: &'static Pool = POOL.get().expect("pool initialized above");
            for idx in 0..p.helpers {
                std::thread::Builder::new()
                    .name(format!("adpsgd-par-{idx}"))
                    .spawn(move || Pool::worker(p, idx))
                    .expect("spawning tensor::par worker");
            }
        });
        pool
    }

    fn worker(pool: &'static Pool, idx: usize) {
        let mut seen = 0u64;
        loop {
            let (task, participating) = {
                let mut st = lock(&pool.m);
                while st.epoch == seen {
                    st = pool.work_cv.wait(st).unwrap_or_else(|p| p.into_inner());
                }
                seen = st.epoch;
                (st.task, idx < st.width)
            };
            let Some(task) = task else { continue };
            if !participating {
                continue;
            }
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task()));
            let mut st = lock(&pool.m);
            if ok.is_err() {
                st.panicked = true;
            }
            st.running -= 1;
            if st.running == 0 {
                pool.done_cv.notify_all();
            }
        }
    }

    /// Broadcast `task` to `width` helpers (>= 1), run it on the calling
    /// thread too, and return once every participant has finished.  The
    /// join-before-return is what makes the `'static` lifetime launder
    /// of `task` sound.
    fn run(&self, width: usize, task: &(dyn Fn() + Sync)) {
        // SAFETY: this function does not return until `running == 0`,
        // i.e. no worker holds the reference past the borrow of `task`.
        let task_static: &'static (dyn Fn() + Sync) =
            unsafe { std::mem::transmute(task) };
        {
            let mut st = lock(&self.m);
            debug_assert_eq!(st.running, 0, "pool gate must serialize jobs");
            st.epoch = st.epoch.wrapping_add(1);
            st.width = width;
            st.running = width;
            st.panicked = false;
            st.task = Some(task_static);
            self.work_cv.notify_all();
        }
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task()));
        let mut st = lock(&self.m);
        while st.running > 0 {
            st = self.done_cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        st.task = None;
        let helper_panicked = st.panicked;
        drop(st);
        if let Err(p) = mine {
            std::panic::resume_unwind(p);
        }
        if helper_panicked {
            panic!("tensor::par worker thread panicked");
        }
    }
}

/// Run `f(i)` for every `i in 0..n_items`, possibly concurrently.
/// Invocations for distinct indices must be independent (they write
/// disjoint data); completion of all of them is guaranteed on return.
/// Falls back to an inline loop when threads() <= 1, the item count is
/// trivial, or the pool is busy with another kernel.
pub(crate) fn for_indices(n_items: usize, f: &(dyn Fn(usize) + Sync)) {
    let inline = || {
        for i in 0..n_items {
            f(i);
        }
    };
    let t = threads();
    if t <= 1 || n_items < 2 {
        return inline();
    }
    let pool = Pool::global();
    let width = pool.helpers.min(t - 1).min(n_items - 1);
    if width == 0 {
        return inline();
    }
    let _gate = match pool.gate.try_lock() {
        Ok(g) => g,
        Err(TryLockError::Poisoned(p)) => p.into_inner(),
        Err(TryLockError::WouldBlock) => return inline(),
    };
    let next = AtomicUsize::new(0);
    let task = move || loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n_items {
            break;
        }
        f(i);
    };
    pool.run(width, &task);
}

/// Apply `f(lo, hi)` over disjoint RCHUNK-aligned subranges covering
/// `0..len`, possibly concurrently.  For elementwise kernels (no
/// cross-element arithmetic) any partition is trivially bit-identical
/// to the serial loop; small inputs run as the single range `(0, len)`.
pub(crate) fn for_ranges(len: usize, f: &(dyn Fn(usize, usize) + Sync)) {
    if len < PAR_MIN || threads() <= 1 {
        if len > 0 {
            f(0, len);
        }
        return;
    }
    let n_chunks = len.div_ceil(RCHUNK);
    for_indices(n_chunks, &|i| {
        let lo = i * RCHUNK;
        f(lo, (lo + RCHUNK).min(len));
    });
}

/// Deterministic parallel reduction over one slice: `chunk_kernel` maps
/// each RCHUNK chunk to its f64 partial; partials are folded **in chunk
/// order** on the calling thread, so the result is bit-identical to the
/// serial `acc += kernel(chunk)` loop at any thread count.
pub(crate) fn reduce1<F>(x: &[f32], chunk_kernel: F) -> f64
where
    F: Fn(&[f32]) -> f64 + Sync,
{
    if x.len() < PAR_MIN || threads() <= 1 {
        let mut acc = 0.0f64;
        for c in x.chunks(RCHUNK) {
            acc += chunk_kernel(c);
        }
        return acc;
    }
    let n_chunks = x.len().div_ceil(RCHUNK);
    let mut partials = vec![0.0f64; n_chunks];
    let out = SendPtr(partials.as_mut_ptr());
    for_indices(n_chunks, &|i| {
        let lo = i * RCHUNK;
        let hi = (lo + RCHUNK).min(x.len());
        // SAFETY: each chunk index is claimed exactly once (disjoint
        // writes) and `partials` outlives the dispatch.
        unsafe { *out.0.add(i) = chunk_kernel(&x[lo..hi]) };
    });
    let mut acc = 0.0f64;
    for p in &partials {
        acc += *p;
    }
    acc
}

/// Two-slice variant of [`reduce1`] (dot, squared deviation).
pub(crate) fn reduce2<F>(a: &[f32], b: &[f32], chunk_kernel: F) -> f64
where
    F: Fn(&[f32], &[f32]) -> f64 + Sync,
{
    debug_assert_eq!(a.len(), b.len());
    if a.len() < PAR_MIN || threads() <= 1 {
        let mut acc = 0.0f64;
        for (ca, cb) in a.chunks(RCHUNK).zip(b.chunks(RCHUNK)) {
            acc += chunk_kernel(ca, cb);
        }
        return acc;
    }
    let n_chunks = a.len().div_ceil(RCHUNK);
    let mut partials = vec![0.0f64; n_chunks];
    let out = SendPtr(partials.as_mut_ptr());
    for_indices(n_chunks, &|i| {
        let lo = i * RCHUNK;
        let hi = (lo + RCHUNK).min(a.len());
        // SAFETY: disjoint writes; `partials` outlives the dispatch.
        unsafe { *out.0.add(i) = chunk_kernel(&a[lo..hi], &b[lo..hi]) };
    });
    let mut acc = 0.0f64;
    for p in &partials {
        acc += *p;
    }
    acc
}

#[cfg(test)]
pub(crate) fn test_serial() -> std::sync::MutexGuard<'static, ()> {
    // serializes tests that flip the global thread count, so concurrent
    // test threads never observe each other's settings mid-assertion
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    lock(LOCK.get_or_init(|| Mutex::new(())))
}

#[cfg(test)]
mod tests {
    use super::super::*;
    use super::*;
    use crate::util::rng::Rng;

    fn vec_of(n: usize, seed: u64) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        Rng::new(seed, 9).fill_normal(&mut v, 1.0);
        v
    }

    /// Thread counts every property is checked across; `cores` last.
    fn sweep() -> Vec<usize> {
        let cores =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        vec![1, 2, 7, cores]
    }

    /// Ragged and aligned lengths: below one chunk, non-multiple-of-8,
    /// chunk-aligned, above the parallel threshold, and large-ragged.
    const LENS: [usize; 7] =
        [0, 5, 1000, RCHUNK, RCHUNK + 3, PAR_MIN + 4097, 5 * RCHUNK + 13];

    /// Run `compute` under each thread count and assert every result is
    /// bit-identical to the threads=1 (serial) result.
    fn assert_bit_identical<T: PartialEq + std::fmt::Debug>(
        label: &str,
        mut compute: impl FnMut() -> T,
    ) {
        let _guard = test_serial();
        set_threads(1);
        let reference = compute();
        for t in sweep() {
            set_threads(t);
            let got = compute();
            assert_eq!(got, reference, "{label}: threads={t} diverged from serial");
        }
        set_threads(0);
    }

    #[test]
    fn reductions_bit_identical_across_threads() {
        for &n in &LENS {
            let a = vec_of(n, 1);
            let b = vec_of(n, 2);
            assert_bit_identical(&format!("dot/{n}"), || dot(&a, &b).to_bits());
            assert_bit_identical(&format!("sq_norm/{n}"), || sq_norm(&a).to_bits());
            assert_bit_identical(&format!("sq_deviation/{n}"), || {
                sq_deviation(&a, &b).to_bits()
            });
        }
    }

    #[test]
    fn elementwise_kernels_bit_identical_across_threads() {
        for &n in &LENS {
            let y0 = vec_of(n, 3);
            let x = vec_of(n, 4);
            assert_bit_identical(&format!("axpy/{n}"), || {
                let mut y = y0.clone();
                axpy(&mut y, 0.25, &x);
                y
            });
            assert_bit_identical(&format!("scale/{n}"), || {
                let mut y = y0.clone();
                scale(&mut y, 0.75);
                y
            });
            assert_bit_identical(&format!("elastic_pull/{n}"), || {
                let mut w = y0.clone();
                elastic_pull(&mut w, &x, 0.4);
                w
            });
            assert_bit_identical(&format!("momentum/{n}"), || {
                let mut w = y0.clone();
                let mut m = x.clone();
                momentum_update(&mut w, &mut m, &y0, 0.01, 0.9);
                (w, m)
            });
        }
    }

    #[test]
    fn mean_rows_and_variance_bit_identical_across_threads() {
        for &n in &[7usize, RCHUNK + 3, PAR_MIN + 4097] {
            let rows_data: Vec<Vec<f32>> = (0..5).map(|i| vec_of(n, 20 + i)).collect();
            let rows: Vec<&[f32]> = rows_data.iter().map(|v| v.as_slice()).collect();
            assert_bit_identical(&format!("mean_rows/{n}"), || {
                let mut out = vec![0.0f32; n];
                mean_rows(&rows, &mut out);
                out
            });
            assert_bit_identical(&format!("param_variance/{n}"), || {
                let mut scratch = vec![0.0f32; n];
                param_variance(&rows, &mut scratch).to_bits()
            });
        }
    }

    #[test]
    fn reduction_matches_naive_f64_closely() {
        // not bit-equality (summation orders differ by design) — a sanity
        // bound that the chunked-lane reduction is numerically right
        let n = PAR_MIN + 777;
        let a = vec_of(n, 5);
        let naive: f64 = a.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        let got = sq_norm(&a);
        assert!((got - naive).abs() < 1e-6 * naive.max(1.0), "{got} vs {naive}");
    }

    #[test]
    fn busy_pool_falls_back_inline_with_identical_results() {
        // nested dispatch: outer kernel holds the pool gate, inner calls
        // (same thread via the chunk closure is impossible — so simulate
        // contention from sibling threads) must still be correct
        let _guard = test_serial();
        set_threads(4);
        let n = PAR_MIN + 1001;
        let a = vec_of(n, 6);
        let expected = {
            set_threads(1);
            let e = sq_norm(&a);
            set_threads(4);
            e
        };
        let results: Vec<f64> = std::thread::scope(|s| {
            (0..6)
                .map(|_| s.spawn(|| sq_norm(&a)))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for r in results {
            assert_eq!(r.to_bits(), expected.to_bits());
        }
        set_threads(0);
    }

    #[test]
    fn thread_count_resolution() {
        let _guard = test_serial();
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
        assert!(threads() >= 1);
    }
}
