//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Self-calibrating: each benchmark first estimates the per-iteration
//! cost, then picks a repetition count targeting a fixed measurement
//! window, runs several samples, and reports min/mean/p50 ns per
//! iteration.  `cargo bench` binaries use `harness = false` and call
//! [`Runner`] directly:
//!
//! ```no_run
//! use adpsgd::util::bench::Runner;
//! let mut r = Runner::from_env("tensor");
//! let xs = vec![1.0f32; 1 << 16];
//! r.bench("sq_norm/64k", || adpsgd::tensor::sq_norm(&xs));
//! r.finish();
//! ```

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // ns per iteration, one per sample
    pub iters_per_sample: u64,
}

impl Measurement {
    pub fn min_ns(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn p50_ns(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        s[s.len() / 2]
    }

    /// Relative spread (max-min)/mean — a noise indicator.
    pub fn spread(&self) -> f64 {
        let max = self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        (max - self.min_ns()) / self.mean_ns()
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark group runner.  Honors two env knobs:
/// * `ADPSGD_BENCH_FAST=1` — shrink windows (CI smoke).
/// * `ADPSGD_BENCH_FILTER=substr` — run matching benchmarks only.
pub struct Runner {
    group: String,
    window: Duration,
    samples: usize,
    filter: Option<String>,
    pub results: Vec<Measurement>,
}

impl Runner {
    pub fn new(group: &str, window: Duration, samples: usize) -> Self {
        println!("\n== bench group: {group} ==");
        Runner { group: group.to_string(), window, samples, filter: None, results: Vec::new() }
    }

    /// Standard construction for `cargo bench` binaries.
    pub fn from_env(group: &str) -> Self {
        let fast = std::env::var("ADPSGD_BENCH_FAST").is_ok();
        let (window, samples) =
            if fast { (Duration::from_millis(20), 3) } else { (Duration::from_millis(250), 7) };
        let mut r = Self::new(group, window, samples);
        r.filter = std::env::var("ADPSGD_BENCH_FILTER").ok();
        r
    }

    fn skip(&self, name: &str) -> bool {
        self.filter.as_deref().map(|f| !name.contains(f)).unwrap_or(false)
    }

    /// Benchmark `f`, which returns a value (black-boxed to defeat DCE).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Option<&Measurement> {
        if self.skip(name) {
            return None;
        }
        // calibrate
        let t0 = Instant::now();
        black_box(f());
        let once = t0.elapsed().max(Duration::from_nanos(20));
        let iters = (self.window.as_nanos() / once.as_nanos()).clamp(1, 1_000_000_000) as u64;

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        let m = Measurement { name: name.to_string(), samples, iters_per_sample: iters };
        println!(
            "{:<44} {:>12}/iter  (min {:>12}, {} iters x {} samples, spread {:.0}%)",
            format!("{}/{}", self.group, m.name),
            fmt_ns(m.p50_ns()),
            fmt_ns(m.min_ns()),
            m.iters_per_sample,
            m.samples.len(),
            m.spread() * 100.0
        );
        self.results.push(m);
        self.results.last()
    }

    /// Benchmark with a derived throughput figure (bytes processed per
    /// iteration → GB/s alongside time).
    pub fn bench_bytes<T, F: FnMut() -> T>(&mut self, name: &str, bytes: u64, f: F) {
        if let Some(m) = self.bench(name, f) {
            let gbps = bytes as f64 / m.p50_ns();
            println!("{:<44} {:>12.2} GB/s", format!("{}/{}", self.group, name), gbps);
        }
    }

    /// Print the group footer. Returns the measurements for assertions.
    pub fn finish(self) -> Vec<Measurement> {
        println!("== {} done: {} benchmarks ==", self.group, self.results.len());
        self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut r = Runner::new("test", Duration::from_millis(2), 2);
        r.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        let ms = r.finish();
        assert_eq!(ms.len(), 1);
        assert!(ms[0].min_ns() > 0.0);
        assert!(ms[0].iters_per_sample >= 1);
    }

    #[test]
    fn ns_formatting() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }

    #[test]
    fn filter_skips() {
        let mut r = Runner::new("test", Duration::from_millis(1), 1);
        r.filter = Some("match".into());
        assert!(r.bench("other", || 1).is_none());
        assert!(r.bench("match-this", || 1).is_some());
        assert_eq!(r.finish().len(), 1);
    }
}
