//! Human-readable formatting for metrics and bench output.

/// Bytes -> "1.50 GiB" style.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = n as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

/// Seconds -> "1.23 s" / "45.6 ms" / "789 us".
pub fn secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Count -> "1.2M" style.
pub fn count(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.2}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 10_000 {
        format!("{:.1}k", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.00 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(secs(2.5), "2.50 s");
        assert_eq!(secs(0.0456), "45.60 ms");
        assert_eq!(secs(12e-6), "12.0 us");
    }

    #[test]
    fn count_units() {
        assert_eq!(count(999), "999");
        assert_eq!(count(1_500_000), "1.50M");
        assert_eq!(count(25_000), "25.0k");
    }
}
