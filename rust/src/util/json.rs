//! Minimal JSON parser — enough for `artifacts/manifest.json`.
//!
//! serde is not in the offline registry, so the runtime parses the AOT
//! manifest with this ~200-line recursive-descent parser.  Supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null); rejects trailing garbage.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors used by the manifest reader ----------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // (surrogate pairs unsupported; manifest is ASCII)
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---------------------------------------------------------------- writer

/// Escape a string per the JSON grammar.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn write_num(out: &mut String, v: f64) {
    if v.is_finite() {
        if v == v.trunc() && v.abs() < 1e15 {
            out.push_str(&format!("{}", v as i64));
        } else {
            out.push_str(&format!("{v}"));
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

impl Json {
    /// Serialize back to canonical compact JSON (round-trips with
    /// [`Json::parse`]; NaN/Inf become null).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\":");
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Convenience constructors for the writer side.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_roundtrips() {
        let src = r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": {"d": false, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_string_compact();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back, "parse(write(v)) != v:\n{text}");
    }

    #[test]
    fn writer_handles_non_finite() {
        let v = Json::Arr(vec![Json::Num(f64::NAN), Json::Num(f64::INFINITY), Json::Num(1.0)]);
        assert_eq!(v.to_string_compact(), "[null,null,1]");
    }

    #[test]
    fn writer_escapes_strings() {
        let v = Json::str("tab\t\"quote\"\u{1}");
        assert_eq!(v.to_string_compact(), "\"tab\\t\\\"quote\\\"\\u0001\"");
    }

    #[test]
    fn obj_builder_orders_keys() {
        let v = Json::obj(vec![("zeta", Json::num(1.0)), ("alpha", Json::Bool(true))]);
        assert_eq!(v.to_string_compact(), "{\"alpha\":true,\"zeta\":1}");
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().get("d").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"k\" :  [ 1 ,\r\n 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
