//! Keyed `Arc` memoization for the process-wide caches (datasets,
//! corpora, artifact manifests): one `static` [`Cache`] per call site,
//! one locking discipline, fallible and infallible flavors.
//!
//! Values are immutable after construction (that is what makes sharing
//! an `Arc` across concurrent campaign runs sound); errors are *not*
//! cached, so a failed build (e.g. a missing artifacts directory) keeps
//! erroring with its actionable message instead of poisoning the key.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex, OnceLock};

/// Declare one of these as a `static` next to the memoized function.
pub type Cache<K, V> = OnceLock<Mutex<HashMap<K, Arc<V>>>>;

/// Get-or-build with a fallible constructor.  The lock is held across
/// the build, serializing concurrent first-builds of the same cache.
pub fn get_or_try_build<K: Eq + Hash, V>(
    cache: &Cache<K, V>,
    key: K,
    build: impl FnOnce() -> anyhow::Result<V>,
) -> anyhow::Result<Arc<V>> {
    let mut map = cache.get_or_init(Default::default).lock().expect("memo cache lock");
    if let Some(v) = map.get(&key) {
        return Ok(Arc::clone(v));
    }
    let v = Arc::new(build()?);
    map.insert(key, Arc::clone(&v));
    Ok(v)
}

/// Get-or-build with an infallible constructor.
pub fn get_or_build<K: Eq + Hash, V>(
    cache: &Cache<K, V>,
    key: K,
    build: impl FnOnce() -> V,
) -> Arc<V> {
    get_or_try_build(cache, key, || Ok(build())).expect("infallible build")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_by_key_and_does_not_cache_errors() {
        static CACHE: Cache<u32, String> = OnceLock::new();
        let a = get_or_build(&CACHE, 1, || "one".to_string());
        let b = get_or_build(&CACHE, 1, || unreachable!("cached"));
        assert!(Arc::ptr_eq(&a, &b));
        let c = get_or_build(&CACHE, 2, || "two".to_string());
        assert!(!Arc::ptr_eq(&a, &c));

        let err: anyhow::Result<Arc<String>> =
            get_or_try_build(&CACHE, 3, || anyhow::bail!("boom"));
        assert!(err.is_err());
        // the failed key retries (errors are not cached)
        let ok = get_or_try_build(&CACHE, 3, || Ok("three".to_string())).unwrap();
        assert_eq!(*ok, "three");
    }
}
