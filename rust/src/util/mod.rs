//! Substrate utilities built in-repo because the build is offline:
//! a counter-based RNG, a minimal JSON parser (for `artifacts/manifest.json`),
//! a property-testing micro-framework, timers and human formatting.

pub mod bench;
pub mod fmt;
pub mod json;
pub mod memo;
pub mod prop;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
