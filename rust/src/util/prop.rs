//! Property-testing micro-framework (proptest is not in the offline
//! registry).  Runs a property over N randomized cases with per-case
//! seeds; on failure, reports the failing seed so the case replays
//! deterministically:
//!
//! ```no_run
//! use adpsgd::util::prop::{forall, Gen};
//! forall("vec-reverse-twice", 64, |g| {
//!     let xs = g.vec_f32(0..100, -1.0, 1.0);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Per-case value generator (thin veneer over [`Rng`] with shape helpers).
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn vec_f32(&mut self, len: Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: Range<usize>, sigma: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, sigma);
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `prop` over `cases` randomized generations.  Panics (with the
/// failing seed in the message) if any case panics.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    // Env override lets a failing seed replay exactly:
    //   ADPSGD_PROP_SEED=123 cargo test failing_test
    let replay = std::env::var("ADPSGD_PROP_SEED").ok().and_then(|s| s.parse::<u64>().ok());
    let seeds: Vec<u64> = match replay {
        Some(s) => vec![s],
        None => (0..cases).collect(),
    };
    for seed in seeds {
        let mut g = Gen { rng: Rng::new(0xADD5_6D ^ seed, seed), seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at seed {seed} \
                 (replay: ADPSGD_PROP_SEED={seed}): {msg}",
                name = name,
                seed = seed,
                msg = msg
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_simple_property() {
        forall("abs-nonneg", 32, |g| {
            let x = g.f32_in(-5.0, 5.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failing_seed() {
        forall("always-fails", 4, |g| {
            let x = g.f32_in(0.0, 1.0);
            assert!(x < 0.0, "x = {x}");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        forall("gen-ranges", 64, |g| {
            let n = g.usize_in(3..10);
            assert!((3..10).contains(&n));
            let v = g.vec_f32(1..5, -2.0, 2.0);
            assert!(!v.is_empty() && v.len() < 5);
            assert!(v.iter().all(|x| (-2.0..2.0).contains(x)));
        });
    }
}
