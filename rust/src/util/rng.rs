//! PCG64-family RNG + Gaussian sampling.
//!
//! The vendored registry has `rand_core` but not `rand`, so the crate
//! carries its own small generator: PCG-XSL-RR 128/64 (O'Neill 2014),
//! the same algorithm as `rand_pcg::Pcg64`.  Deterministic, seedable,
//! splittable by stream — every worker node derives an independent
//! stream from (seed, node_id) so runs are exactly reproducible.

/// PCG-XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// New generator; `stream` selects an independent sequence (odd-ified
    /// internally), so `Rng::new(seed, node_id)` gives per-node streams.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full float precision
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; grads are generated in bulk anyway).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Fill a slice with N(0, sigma^2).
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(1, 2); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(1, 2); move |_| r.next_u64() }).collect();
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(1, 3); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(42, 0);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7, 1);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3, 3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9, 0);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
