//! Wall-clock timing helpers used by the coordinator's metrics and the
//! bench harnesses.

use std::time::{Duration, Instant};

/// Accumulating stopwatch: `start`/`stop` pairs add into a total.
#[derive(Debug, Clone)]
pub struct Timer {
    total: Duration,
    started: Option<Instant>,
    laps: u64,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Timer { total: Duration::ZERO, started: None, laps: 0 }
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "timer already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
            self.laps += 1;
        }
    }

    /// Time a closure, accumulating its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    pub fn secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn laps(&self) -> u64 {
        self.laps
    }

    pub fn mean_secs(&self) -> f64 {
        if self.laps == 0 {
            0.0
        } else {
            self.secs() / self.laps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_laps() {
        let mut t = Timer::new();
        for _ in 0..3 {
            t.time(|| std::thread::sleep(Duration::from_millis(2)));
        }
        assert_eq!(t.laps(), 3);
        assert!(t.secs() >= 0.006);
        assert!(t.mean_secs() >= 0.002);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut t = Timer::new();
        t.stop();
        assert_eq!(t.laps(), 0);
        assert_eq!(t.secs(), 0.0);
    }
}
