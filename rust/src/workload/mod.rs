//! Pure-rust differentiable workloads.
//!
//! The statistics figures (Fig 1–3, Table I sweeps) need thousands of
//! 16-node × 4000-iteration runs; executing those through PJRT would be
//! needlessly slow and adds nothing — the paper's claims there are about
//! the *coordination statistics*, not the model.  These workloads give
//! the coordinator a fast in-process `grad`/`eval` with hand-written
//! backprop.  The HLO/PJRT path ([`crate::runtime`]) is the product
//! path and drives the end-to-end examples; both implement [`Engine`]
//! (see [`crate::coordinator::engine`]).

use crate::data::Batch;
use crate::util::rng::Rng;

/// A differentiable objective over a flat parameter vector.
pub trait Workload: Send {
    fn n_params(&self) -> usize;
    /// Fill `w` with the initial point (all nodes then broadcast rank 0's).
    fn init(&self, rng: &mut Rng, w: &mut [f32]);
    /// Compute loss and gradient at `w` on `batch` (g is overwritten).
    fn loss_grad(&mut self, w: &[f32], batch: &Batch, g: &mut [f32]) -> f32;
    /// (loss, accuracy) on a batch.
    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, f32);
    fn boxed_clone(&self) -> Box<dyn Workload>;
}

// ---------------------------------------------------------------------------
// quadratic bowl (for clean invariant tests)
// ---------------------------------------------------------------------------

/// `f(w) = E_x 0.5 ||w - x||^2` over batch rows: the stochastic quadratic
/// used in distributed-SGD analyses.  Optimum = data mean; gradient noise
/// = batch-mean noise.  Accuracy is reported as 0.
#[derive(Debug, Clone)]
pub struct Quadratic {
    pub dim: usize,
}

impl Workload for Quadratic {
    fn n_params(&self) -> usize {
        self.dim
    }

    fn init(&self, rng: &mut Rng, w: &mut [f32]) {
        rng.fill_normal(w, 1.0);
    }

    fn loss_grad(&mut self, w: &[f32], batch: &Batch, g: &mut [f32]) -> f32 {
        let Batch::Class { x, batch, dim, .. } = batch else {
            panic!("Quadratic expects Class batches")
        };
        assert_eq!(*dim, self.dim);
        // grad = w - mean_x ; loss = mean 0.5||w - x_b||^2
        let inv = 1.0 / *batch as f32;
        let mut loss = 0.0f64;
        g.copy_from_slice(w);
        for b in 0..*batch {
            let row = &x[b * dim..(b + 1) * dim];
            loss += 0.5 * crate::tensor::sq_deviation(w, row) * inv as f64;
            for (gi, xi) in g.iter_mut().zip(row) {
                *gi -= xi * inv;
            }
        }
        loss as f32
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, f32) {
        let mut g = vec![0.0; self.dim];
        (self.loss_grad(w, batch, &mut g), 0.0)
    }

    fn boxed_clone(&self) -> Box<dyn Workload> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------------
// MLP classifier with manual backprop
// ---------------------------------------------------------------------------

/// Multi-layer perceptron: dims[0] -> relu(dims[1]) -> ... -> dims.last()
/// with softmax cross-entropy.  `dims = [input, hidden..., classes]`.
/// Parameter layout matches the python L2 `mlp` (per layer: W then b),
/// so HLO and native runs of the same architecture are interchangeable.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub dims: Vec<usize>,
    // scratch (per instance; workloads are per-thread)
    acts: Vec<Vec<f32>>,   // activations per layer boundary
    deltas: Vec<Vec<f32>>, // backprop deltas
    batch_cap: usize,
}

impl Mlp {
    pub fn new(dims: Vec<usize>) -> Self {
        assert!(dims.len() >= 2);
        Mlp { dims, acts: Vec::new(), deltas: Vec::new(), batch_cap: 0 }
    }

    /// GoogLeNet-role preset: compute-heavy relative to its size.
    pub fn compute_bound(input_dim: usize, hidden: usize, classes: usize) -> Self {
        Mlp::new(vec![input_dim, hidden, hidden, classes])
    }

    fn ensure_scratch(&mut self, batch: usize) {
        if self.batch_cap >= batch && !self.acts.is_empty() {
            return;
        }
        self.acts = self.dims.iter().map(|&d| vec![0.0; batch * d]).collect();
        self.deltas = self.dims.iter().map(|&d| vec![0.0; batch * d]).collect();
        self.batch_cap = batch;
    }

    fn layer_sizes(&self) -> Vec<(usize, usize)> {
        self.dims.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// offsets of (W, b) per layer in the flat vector
    fn offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut off = 0;
        for (i, o) in self.layer_sizes() {
            out.push((off, off + i * o));
            off += i * o + o;
        }
        out
    }

    /// forward into self.acts; returns logits slice index
    fn forward(&mut self, w: &[f32], x: &[f32], batch: usize) {
        self.ensure_scratch(batch);
        let sizes = self.layer_sizes();
        let offs = self.offsets();
        self.acts[0][..batch * self.dims[0]].copy_from_slice(x);
        for (l, &(din, dout)) in sizes.iter().enumerate() {
            let (w_off, b_off) = offs[l];
            let wm = &w[w_off..w_off + din * dout];
            let bm = &w[b_off..b_off + dout];
            let last = l + 1 == sizes.len();
            // split borrow: acts[l] input, acts[l+1] output
            let (head, tail) = self.acts.split_at_mut(l + 1);
            let input = &head[l][..batch * din];
            let out = &mut tail[0][..batch * dout];
            for b in 0..batch {
                let xr = &input[b * din..(b + 1) * din];
                let yr = &mut out[b * dout..(b + 1) * dout];
                yr.copy_from_slice(bm);
                // i-k-j loop, row-major W[din][dout]: autovectorizes
                for (k, &xv) in xr.iter().enumerate() {
                    if xv != 0.0 {
                        let wrow = &wm[k * dout..(k + 1) * dout];
                        for (yv, wv) in yr.iter_mut().zip(wrow) {
                            *yv += xv * wv;
                        }
                    }
                }
                if !last {
                    for v in yr.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
    }

    /// softmax-CE loss + dlogits (into deltas.last)
    fn loss_and_dlogits(&mut self, y: &[i32], batch: usize) -> f32 {
        let c = *self.dims.last().unwrap();
        let l = self.dims.len() - 1;
        let logits = &self.acts[l][..batch * c];
        let dl = &mut self.deltas[l][..batch * c];
        let mut loss = 0.0f64;
        let invb = 1.0 / batch as f32;
        for b in 0..batch {
            let row = &logits[b * c..(b + 1) * c];
            let drow = &mut dl[b * c..(b + 1) * c];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let mut z = 0.0f32;
            for (d, &v) in drow.iter_mut().zip(row) {
                *d = (v - mx).exp();
                z += *d;
            }
            let yi = y[b] as usize;
            loss += -(((row[yi] - mx) as f64) - (z as f64).ln());
            for d in drow.iter_mut() {
                *d = *d / z * invb;
            }
            drow[yi] -= invb;
        }
        (loss * invb as f64) as f32
    }

    fn backward(&mut self, w: &[f32], g: &mut [f32], batch: usize) {
        let sizes = self.layer_sizes();
        let offs = self.offsets();
        g.iter_mut().for_each(|v| *v = 0.0);
        for l in (0..sizes.len()).rev() {
            let (din, dout) = sizes[l];
            let (w_off, b_off) = offs[l];
            // dW = act[l]^T @ delta[l+1]; db = sum delta; dact[l] = delta @ W^T
            let (d_head, d_tail) = self.deltas.split_at_mut(l + 1);
            let delta_out = &d_tail[0][..batch * dout];
            let act_in = &self.acts[l][..batch * din];
            {
                let gw = &mut g[w_off..w_off + din * dout];
                for b in 0..batch {
                    let ar = &act_in[b * din..(b + 1) * din];
                    let dr = &delta_out[b * dout..(b + 1) * dout];
                    for (k, &av) in ar.iter().enumerate() {
                        if av != 0.0 {
                            let gr = &mut gw[k * dout..(k + 1) * dout];
                            for (gv, dv) in gr.iter_mut().zip(dr) {
                                *gv += av * dv;
                            }
                        }
                    }
                }
            }
            {
                let gb = &mut g[b_off..b_off + dout];
                for b in 0..batch {
                    let dr = &delta_out[b * dout..(b + 1) * dout];
                    for (gv, dv) in gb.iter_mut().zip(dr) {
                        *gv += dv;
                    }
                }
            }
            if l > 0 {
                let wm = &w[w_off..w_off + din * dout];
                let delta_in = &mut d_head[l][..batch * din];
                let act_in = &self.acts[l][..batch * din];
                for b in 0..batch {
                    let dr = &delta_out[b * dout..(b + 1) * dout];
                    let di = &mut delta_in[b * din..(b + 1) * din];
                    let ai = &act_in[b * din..(b + 1) * din];
                    for k in 0..din {
                        // relu mask: act==0 -> no grad
                        if ai[k] > 0.0 {
                            let wrow = &wm[k * dout..(k + 1) * dout];
                            let mut acc = 0.0f32;
                            for (wv, dv) in wrow.iter().zip(dr) {
                                acc += wv * dv;
                            }
                            di[k] = acc;
                        } else {
                            di[k] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

impl Workload for Mlp {
    fn n_params(&self) -> usize {
        self.layer_sizes().iter().map(|(i, o)| i * o + o).sum()
    }

    fn init(&self, rng: &mut Rng, w: &mut [f32]) {
        let offs = self.offsets();
        for (l, &(din, dout)) in self.layer_sizes().iter().enumerate() {
            let (w_off, b_off) = offs[l];
            let scale = (2.0 / din as f32).sqrt(); // He init (relu net)
            rng.fill_normal(&mut w[w_off..w_off + din * dout], scale);
            w[b_off..b_off + dout].iter_mut().for_each(|v| *v = 0.0);
        }
    }

    fn loss_grad(&mut self, w: &[f32], batch: &Batch, g: &mut [f32]) -> f32 {
        let Batch::Class { x, y, batch, dim } = batch else {
            panic!("Mlp expects Class batches")
        };
        assert_eq!(*dim, self.dims[0]);
        self.forward(w, x, *batch);
        let loss = self.loss_and_dlogits(y, *batch);
        self.backward(w, g, *batch);
        loss
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, f32) {
        let Batch::Class { x, y, batch, dim } = batch else {
            panic!("Mlp expects Class batches")
        };
        assert_eq!(*dim, self.dims[0]);
        self.forward(w, x, *batch);
        let c = *self.dims.last().unwrap();
        let l = self.dims.len() - 1;
        let logits = &self.acts[l][..batch * c];
        let mut correct = 0usize;
        let mut loss = 0.0f64;
        for b in 0..*batch {
            let row = &logits[b * c..(b + 1) * c];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let z: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let yi = y[b] as usize;
            loss += -(((row[yi] - mx) as f64) - (z as f64).ln());
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
            if argmax == yi {
                correct += 1;
            }
        }
        ((loss / *batch as f64) as f32, correct as f32 / *batch as f32)
    }

    fn boxed_clone(&self) -> Box<dyn Workload> {
        Box::new(Mlp::new(self.dims.clone()))
    }
}

/// Softmax (multinomial logistic) regression: the `dims.len() == 2` MLP.
pub fn logreg(input_dim: usize, classes: usize) -> Mlp {
    Mlp::new(vec![input_dim, classes])
}

/// Build a named native workload.
pub fn build(name: &str, cfg: &crate::config::WorkloadConfig) -> anyhow::Result<Box<dyn Workload>> {
    Ok(match name {
        "quadratic" => Box::new(Quadratic { dim: cfg.input_dim }),
        "logreg" => Box::new(logreg(cfg.input_dim, cfg.classes)),
        "mlp" => Box::new(Mlp::new(vec![cfg.input_dim, cfg.hidden, cfg.classes])),
        // "failing[:rank:step]" is the chaos-test hook: same model as
        // "mlp"; the error injection lives in the engine wrapper
        n if n.starts_with("failing") => {
            Box::new(Mlp::new(vec![cfg.input_dim, cfg.hidden, cfg.classes]))
        }
        "mlp_deep" => {
            Box::new(Mlp::new(vec![cfg.input_dim, cfg.hidden, cfg.hidden, cfg.classes]))
        }
        // VGG16-role: parameter-heavy (comm-bound). hidden is widened.
        "mlp_wide" => Box::new(Mlp::new(vec![cfg.input_dim, cfg.hidden * 8, cfg.classes])),
        other => anyhow::bail!("unknown native workload {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthClass;
    use crate::util::prop::forall;

    fn fd_check(wl: &mut dyn Workload, batch: &Batch, probes: usize, seed: u64) {
        let n = wl.n_params();
        let mut w = vec![0.0f32; n];
        wl.init(&mut Rng::new(seed, 0), &mut w);
        let mut g = vec![0.0f32; n];
        let loss0 = wl.loss_grad(&w, batch, &mut g);
        assert!(loss0.is_finite());
        let mut rng = Rng::new(seed, 1);
        let eps = 1e-3f32;
        for _ in 0..probes {
            let i = rng.below(n);
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let mut scratch = vec![0.0f32; n];
            let lp = wl.loss_grad(&wp, batch, &mut scratch);
            let lm = wl.loss_grad(&wm, batch, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps);
            let tol = 2e-2 * (1.0 + fd.abs());
            assert!((fd - g[i]).abs() < tol, "param {i}: fd={fd} analytic={}", g[i]);
        }
    }

    #[test]
    fn quadratic_grad_matches_fd() {
        let d = SynthClass::new(0, 16, 4, 1.0, 0.0);
        let batch = d.sample(&mut Rng::new(1, 0), 8);
        fd_check(&mut Quadratic { dim: 16 }, &batch, 8, 3);
    }

    #[test]
    fn quadratic_converges_to_mean() {
        let d = SynthClass::new(0, 8, 2, 0.1, 0.0);
        let mut wl = Quadratic { dim: 8 };
        let mut w = vec![5.0f32; 8];
        let mut g = vec![0.0f32; 8];
        let mut rng = Rng::new(2, 0);
        for _ in 0..500 {
            let b = d.sample(&mut rng, 32);
            wl.loss_grad(&w, &b, &mut g);
            crate::tensor::axpy(&mut w, -0.2, &g);
        }
        // optimum is the mixture mean; loss should be near its floor
        let b = d.sample(&mut rng, 256);
        let (loss, _) = wl.eval(&w, &b);
        let mut w_bad = vec![5.0f32; 8];
        let (loss_bad, _) = wl.eval(&mut w_bad, &b);
        assert!(loss < loss_bad * 0.2, "loss {loss} vs {loss_bad}");
    }

    #[test]
    fn mlp_grad_matches_fd() {
        let d = SynthClass::new(5, 10, 3, 0.8, 0.0);
        let batch = d.sample(&mut Rng::new(6, 0), 4);
        fd_check(&mut Mlp::new(vec![10, 12, 3]), &batch, 12, 7);
    }

    #[test]
    fn deep_mlp_grad_matches_fd() {
        let d = SynthClass::new(8, 6, 3, 0.8, 0.0);
        let batch = d.sample(&mut Rng::new(9, 0), 4);
        fd_check(&mut Mlp::new(vec![6, 8, 8, 3]), &batch, 12, 11);
    }

    #[test]
    fn logreg_grad_matches_fd() {
        let d = SynthClass::new(1, 8, 4, 1.0, 0.0);
        let batch = d.sample(&mut Rng::new(2, 0), 8);
        fd_check(&mut logreg(8, 4), &batch, 8, 5);
    }

    #[test]
    fn mlp_sgd_learns_synthetic_task() {
        let d = SynthClass::new(3, 16, 4, 0.4, 0.0);
        let mut wl = Mlp::new(vec![16, 32, 4]);
        let n = wl.n_params();
        let mut w = vec![0.0f32; n];
        wl.init(&mut Rng::new(0, 0), &mut w);
        let mut g = vec![0.0f32; n];
        let mut opt = crate::optim::MomentumSgd::new(n, 0.9);
        let mut rng = Rng::new(4, 0);
        for _ in 0..300 {
            let b = d.sample(&mut rng, 32);
            wl.loss_grad(&w, &b, &mut g);
            opt.step(&mut w, &g, 0.05);
        }
        let b = d.sample(&mut rng, 512);
        let (loss, acc) = wl.eval(&w, &b);
        assert!(acc > 0.9, "acc {acc} loss {loss}");
    }

    #[test]
    fn param_count_matches_python_mlp_small() {
        // python preset mlp_small: 256 -> 128 -> 128 -> 10 = 50698 params
        let m = Mlp::new(vec![256, 128, 128, 10]);
        assert_eq!(m.n_params(), 50698);
    }

    #[test]
    fn grad_is_deterministic() {
        forall("mlp-grad-deterministic", 8, |gen| {
            let din = gen.usize_in(2..12);
            let c = gen.usize_in(2..5);
            let d = SynthClass::new(gen.seed, din, c, 1.0, 0.0);
            let batch = d.sample(&mut Rng::new(gen.seed, 9), 4);
            let mut wl = Mlp::new(vec![din, 6, c]);
            let n = wl.n_params();
            let mut w = vec![0.0f32; n];
            wl.init(&mut Rng::new(gen.seed, 3), &mut w);
            let mut g1 = vec![0.0f32; n];
            let mut g2 = vec![0.0f32; n];
            let l1 = wl.loss_grad(&w, &batch, &mut g1);
            let l2 = wl.loss_grad(&w, &batch, &mut g2);
            assert_eq!(l1, l2);
            assert_eq!(g1, g2);
        });
    }
}
