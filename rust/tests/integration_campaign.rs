//! Integration tests for the experiment API redesign: typed
//! `StrategySpec` ⇄ TOML ⇄ dotted-override round trips (including the
//! legacy flat-key compat path), strategy alias coverage, the session
//! builder, and end-to-end campaign execution.

use adpsgd::collective::Algo;
use adpsgd::config::{spec, ExperimentConfig, StrategySpec};
use adpsgd::config::toml::TomlDoc;
use adpsgd::experiment::Campaign;
use adpsgd::period::Strategy;

fn nondefault_specs() -> Vec<StrategySpec> {
    vec![
        StrategySpec::Full,
        StrategySpec::Constant { period: 11 },
        StrategySpec::Adaptive { p_init: 3, warmup_iters: 17, ks_frac: 0.2, low: 0.6, high: 1.4 },
        StrategySpec::Decreasing { first: 21, second: 3 },
        StrategySpec::Qsgd { levels: 15, bucket: 128 },
        StrategySpec::Piecewise { schedule: "0:2,500:9".into() },
        StrategySpec::Easgd { period: 6, alpha: 0.25 },
        StrategySpec::TopK { frac: 0.0625 },
    ]
}

#[test]
fn spec_to_toml_to_spec_roundtrip() {
    for spec in nondefault_specs() {
        let text = spec.to_toml();
        let doc = TomlDoc::parse(&text).unwrap_or_else(|e| panic!("{spec:?}: {e}\n{text}"));
        let cfg = ExperimentConfig::from_doc(&doc).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        assert_eq!(cfg.sync.strategy, spec.kind());
        assert_eq!(cfg.sync.spec(), spec, "nested-TOML round trip for {spec:?}");
    }
}

#[test]
fn spec_to_dotted_overrides_roundtrip() {
    // the same knobs as dotted CLI overrides instead of a file
    let cases: Vec<(Vec<(&str, &str)>, StrategySpec)> = vec![
        (
            vec![
                ("sync.strategy", "adaptive"),
                ("sync.adaptive.p_init", "3"),
                ("sync.adaptive.warmup_iters", "17"),
                ("sync.adaptive.ks_frac", "0.2"),
                ("sync.adaptive.low", "0.6"),
                ("sync.adaptive.high", "1.4"),
            ],
            StrategySpec::Adaptive {
                p_init: 3,
                warmup_iters: 17,
                ks_frac: 0.2,
                low: 0.6,
                high: 1.4,
            },
        ),
        (
            vec![
                ("sync.strategy", "qsgd"),
                ("sync.qsgd.levels", "15"),
                ("sync.qsgd.bucket", "128"),
            ],
            StrategySpec::Qsgd { levels: 15, bucket: 128 },
        ),
        (
            vec![
                ("sync.strategy", "easgd"),
                ("sync.easgd.period", "6"),
                ("sync.easgd.alpha", "0.25"),
            ],
            StrategySpec::Easgd { period: 6, alpha: 0.25 },
        ),
        (
            vec![("sync.strategy", "piecewise"), ("sync.piecewise.schedule", "\"0:2,500:9\"")],
            StrategySpec::Piecewise { schedule: "0:2,500:9".into() },
        ),
    ];
    for (overrides, want) in cases {
        let ov: Vec<(String, String)> =
            overrides.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        let cfg = ExperimentConfig::from_overrides(&ov).unwrap_or_else(|e| panic!("{want:?}: {e}"));
        assert_eq!(cfg.sync.spec(), want);
    }
}

#[test]
fn legacy_flat_keys_still_load_and_agree_with_nested() {
    // the compat path: old flat [sync] keys produce the same typed spec
    let flat = TomlDoc::parse(
        "[sync]\nstrategy = \"adpsgd\"\np_init = 3\nwarmup_iters = 17\nks_frac = 0.2\nlow = 0.6\nhigh = 1.4",
    )
    .unwrap();
    let nested = TomlDoc::parse(
        "[sync]\nstrategy = \"adaptive\"\n\n[sync.adaptive]\np_init = 3\nwarmup_iters = 17\nks_frac = 0.2\nlow = 0.6\nhigh = 1.4",
    )
    .unwrap();
    let a = ExperimentConfig::from_doc(&flat).unwrap();
    let b = ExperimentConfig::from_doc(&nested).unwrap();
    assert_eq!(a.sync.spec(), b.sync.spec());

    // legacy dotted overrides keep loading too (matching strategy)
    let ov =
        vec![("sync.strategy".to_string(), "qsgd".to_string()),
             ("sync.qsgd_levels".to_string(), "31".to_string())];
    let cfg = ExperimentConfig::from_overrides(&ov).unwrap();
    assert_eq!(cfg.sync.spec(), StrategySpec::Qsgd { levels: 31, bucket: 512 });
}

#[test]
fn strategy_alias_coverage() {
    let cases: [(&str, Strategy); 11] = [
        ("full", Strategy::Full),
        ("fullsgd", Strategy::Full),
        ("constant", Strategy::Constant),
        ("cpsgd", Strategy::Constant),
        ("adaptive", Strategy::Adaptive),
        ("adpsgd", Strategy::Adaptive),
        ("decreasing", Strategy::Decreasing),
        ("qsgd", Strategy::Qsgd),
        ("piecewise", Strategy::Piecewise),
        ("easgd", Strategy::Easgd),
        ("topk", Strategy::TopK),
    ];
    for (alias, want) in cases {
        assert_eq!(alias.parse::<Strategy>().unwrap(), want, "{alias}");
    }
    assert!("mesh".parse::<Strategy>().is_err());
    assert!("ADPSGD".parse::<Strategy>().is_err(), "aliases are lowercase");
    // every alias table agrees with FromStr, and canonical names parse
    for kind in spec::ALL_STRATEGIES {
        for table in spec::table_names(kind) {
            assert_eq!(table.parse::<Strategy>().unwrap(), kind);
        }
    }
}

#[test]
fn misplaced_cli_knob_reports_valid_keys() {
    let path = {
        use std::io::Write;
        let dir = std::env::temp_dir().join(format!("adpsgd_camp_it_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("adaptive.toml");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(b"[sync]\nstrategy = \"adpsgd\"\n").unwrap();
        p
    };
    let ov = vec![("sync.qsgd_levels".to_string(), "15".to_string())];
    let err = ExperimentConfig::from_file(path.to_str().unwrap(), &ov).unwrap_err().to_string();
    assert!(err.contains("qsgd knob"), "{err}");
    assert!(err.contains("sync.adaptive.p_init"), "{err}");
    assert!(err.contains("sync.p_init"), "legacy form listed too: {err}");
}

#[test]
fn swept_strategy_overrides_accepted_and_applied() {
    // the `adpsgd campaign` path: base strategy adaptive, sweeping qsgd —
    // qsgd knobs arrive via lenient application, flow into the swept
    // run's spec, and validate against the swept set
    let ov = vec![("sync.qsgd.levels".to_string(), "15".to_string())];
    let mut base = quick_base(); // default strategy: adaptive
    base.apply_overrides_lenient(&ov).unwrap();
    ExperimentConfig::check_override_keys(&[Strategy::Adaptive, Strategy::Qsgd], &ov).unwrap();
    assert_eq!(
        base.sync.spec_of(Strategy::Qsgd),
        StrategySpec::Qsgd { levels: 15, bucket: 512 }
    );
    // the same override stays rejected for a single-strategy run
    let err =
        ExperimentConfig::check_override_keys(&[Strategy::Adaptive], &ov).unwrap_err().to_string();
    assert!(err.contains("configures strategy qsgd"), "{err}");
}

fn quick_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.nodes = 2;
    cfg.iters = 60;
    cfg.batch_per_node = 8;
    cfg.eval_every = 30;
    cfg.workload.input_dim = 24;
    cfg.workload.hidden = 12;
    cfg.workload.eval_batches = 2;
    cfg.optim.schedule = adpsgd::config::LrSchedule::Const;
    cfg.sync.period = 4;
    cfg.sync.p_init = 2;
    cfg.sync.warmup_iters = 4;
    cfg
}

#[test]
fn campaign_strategy_by_collective_sweep_end_to_end() {
    // the `adpsgd campaign --quick` shape: strategy × collective
    let base = quick_base();
    let report = Campaign::builder("it_campaign", base.clone())
        .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
        .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .collectives(&[Algo::Ring, Algo::Flat])
        .parallelism(2)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(report.runs.len(), 4);
    // both collectives reduce bit-identically per strategy
    for s in ["cpsgd", "adpsgd"] {
        let ring = report.get(&format!("{s}_ring"));
        let flat = report.get(&format!("{s}_flat"));
        assert_eq!(ring.final_train_loss, flat.final_train_loss, "{s}");
        assert_eq!(ring.syncs, flat.syncs, "{s}");
    }
    // JSON summary carries the headline numbers
    let json = report.to_json().to_string_compact();
    for key in ["runs_per_sec", "total_modeled_comm_secs", "total_wire_bytes", "adpsgd_flat"] {
        assert!(json.contains(key), "missing {key}: {json}");
    }
}

#[test]
fn campaign_bandwidth_axis_reprices_comm() {
    use adpsgd::config::NetConfig;
    let base = quick_base();
    let report = Campaign::builder("net_sweep", base.clone())
        .strategy("full", StrategySpec::Full)
        .net("100g", NetConfig::infiniband_100g())
        .net("10g", NetConfig::ethernet_10g())
        .build()
        .unwrap()
        .run()
        .unwrap();
    let fast = report.get("full_100g");
    let slow = report.get("full_10g");
    // identical training, different modeled cost
    assert_eq!(fast.final_train_loss, slow.final_train_loss);
    assert!(slow.ledger.total_secs() > fast.ledger.total_secs());
}
