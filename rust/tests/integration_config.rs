//! Integration tests for the configuration pipeline: TOML file →
//! overrides → validated `ExperimentConfig` → actual run; plus CLI
//! parsing round-trips the launcher relies on.

use adpsgd::cli::Args;
use adpsgd::config::{Backend, ExperimentConfig, LrSchedule};
use adpsgd::experiment::Experiment;
use adpsgd::period::Strategy;
use std::io::Write;

fn temp_file(name: &str, content: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("adpsgd_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(content.as_bytes()).unwrap();
    path
}

const FULL_TOML: &str = r#"
name = "it_config"
seed = 7
nodes = 4
iters = 120
batch_per_node = 16
eval_every = 60

[workload]
backend = "native"
model = "mlp"
input_dim = 32
hidden = 16
classes = 5
noise = 0.8
label_noise = 0.0
eval_batches = 4

[optim]
lr0 = 0.05
momentum = 0.9
schedule = "step"
boundaries = [60, 90]
factor = 0.1

[sync]
strategy = "adpsgd"
p_init = 2
warmup_iters = 10
ks_frac = 0.25
low = 0.7
high = 1.3

[net]
bandwidth_gbps = 10.0
latency_us = 25.0
"#;

#[test]
fn toml_file_to_run_end_to_end() {
    let path = temp_file("full.toml", FULL_TOML);
    let cfg = ExperimentConfig::from_file(path.to_str().unwrap(), &[]).unwrap();
    assert_eq!(cfg.name, "it_config");
    assert_eq!(cfg.nodes, 4);
    assert_eq!(cfg.sync.strategy, Strategy::Adaptive);
    assert_eq!(cfg.workload.classes, 5);
    assert_eq!(cfg.net.bandwidth_gbps, 10.0);

    let r = Experiment::from_config(cfg).unwrap().run().unwrap();
    assert!(r.final_train_loss.is_finite());
    assert!(r.best_eval_acc > 0.3);
}

#[test]
fn overrides_beat_file_values() {
    let path = temp_file("ovr.toml", FULL_TOML);
    let overrides = vec![
        ("nodes".to_string(), "2".to_string()),
        ("sync.strategy".to_string(), "\"cpsgd\"".to_string()),
        ("sync.period".to_string(), "6".to_string()),
        ("optim.lr0".to_string(), "0.1".to_string()),
    ];
    let cfg = ExperimentConfig::from_file(path.to_str().unwrap(), &overrides).unwrap();
    assert_eq!(cfg.nodes, 2);
    assert_eq!(cfg.sync.strategy, Strategy::Constant);
    assert_eq!(cfg.sync.period, 6);
    assert!((cfg.optim.lr0 - 0.1).abs() < 1e-6);
    // untouched keys keep file values
    assert_eq!(cfg.iters, 120);
}

#[test]
fn bare_string_override_is_accepted() {
    // CLI passes raw values; the loader must handle unquoted strings too
    let path = temp_file("raw.toml", FULL_TOML);
    let overrides = vec![("sync.strategy".to_string(), "full".to_string())];
    let cfg = ExperimentConfig::from_file(path.to_str().unwrap(), &overrides).unwrap();
    assert_eq!(cfg.sync.strategy, Strategy::Full);
}

#[test]
fn invalid_override_rejected() {
    let path = temp_file("bad.toml", FULL_TOML);
    let overrides = vec![("nodes".to_string(), "0".to_string())];
    assert!(ExperimentConfig::from_file(path.to_str().unwrap(), &overrides).is_err());
}

#[test]
fn missing_file_errors_with_path() {
    let err = ExperimentConfig::from_file("/nonexistent/xyz.toml", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("xyz.toml"));
}

#[test]
fn cli_args_to_overrides_roundtrip() {
    let argv: Vec<String> = ["run", "--config", "exp.toml", "--sync.period=9", "--net.latency_us", "50"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = Args::parse(argv, &[]).unwrap();
    assert_eq!(args.subcommand.as_deref(), Some("run"));
    let ov = args.config_overrides();
    assert!(ov.contains(&("sync.period".into(), "9".into())));
    assert!(ov.contains(&("net.latency_us".into(), "50".into())));
    // non-dotted options are not config overrides
    assert!(!ov.iter().any(|(k, _)| k == "config"));
}

#[test]
fn default_config_runs_hlo_backend_spec() {
    // Backend::Hlo with a missing artifacts dir must fail *at run setup*
    // with an actionable message, not panic mid-training.
    let mut cfg = ExperimentConfig::default();
    cfg.nodes = 2;
    cfg.iters = 4;
    cfg.workload.backend = Backend::Hlo("mlp_small".into());
    cfg.artifacts_dir = "/definitely/not/here".into();
    let err = Experiment::from_config(cfg).unwrap().run().unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}

#[test]
fn shipped_config_presets_parse_and_validate() {
    for preset in
        ["cifar_adpsgd", "imagenet_warmup", "e2e_transformer", "throttled_10g"]
    {
        let path = format!("configs/{preset}.toml");
        let cfg = ExperimentConfig::from_file(&path, &[]).unwrap_or_else(|e| {
            panic!("{path}: {e:#}");
        });
        cfg.validate().unwrap();
    }
}

#[test]
fn preset_runs_shortened() {
    // the CIFAR preset actually executes when shortened via overrides
    // (nested override form: the preset's [sync.adaptive] table would
    // beat a legacy flat override for the same knob)
    let overrides = vec![
        ("iters".to_string(), "60".to_string()),
        ("nodes".to_string(), "2".to_string()),
        ("eval_every".to_string(), "30".to_string()),
        ("optim.boundaries".to_string(), "[30, 45]".to_string()),
        ("sync.adaptive.warmup_iters".to_string(), "4".to_string()),
    ];
    let cfg = ExperimentConfig::from_file("configs/cifar_adpsgd.toml", &overrides).unwrap();
    assert_eq!(cfg.sync.warmup_iters, 4, "nested override must take effect");
    let r = Experiment::from_config(cfg).unwrap().run().unwrap();
    assert!(r.final_train_loss.is_finite());
}

#[test]
fn schedule_variants_validate() {
    for schedule in [
        LrSchedule::Const,
        LrSchedule::StepDecay { boundaries: vec![10], factor: 0.5 },
        LrSchedule::Warmup { warmup_iters: 5, warmup_factor: 4.0, boundaries: vec![20], factor: 0.1 },
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.nodes = 2;
        cfg.iters = 30;
        cfg.batch_per_node = 8;
        cfg.workload.input_dim = 16;
        cfg.workload.hidden = 8;
        cfg.optim.schedule = schedule;
        cfg.eval_every = 0;
        let r = Experiment::from_config(cfg).unwrap().run().unwrap();
        assert!(r.final_train_loss.is_finite());
    }
}
