//! Integration tests for the dispatch subsystem: the content-addressed
//! run cache end to end (hash stability, bit-identical hits, deliberate
//! busting, corruption handling, GC), subprocess workers over the JSONL
//! protocol (including a killed worker retried on a fresh child, a
//! SIGSTOPped worker recovered by the heartbeat deadline, and stale
//! terminal frames discarded), and the deterministic merge across job
//! counts.
//!
//! Subprocess tests that kill or freeze workers use a private
//! [`WorkerPool`] so they never target another test's children through
//! the process-wide shared pool.

use adpsgd::config::{ExperimentConfig, LrSchedule, StrategySpec};
use adpsgd::dispatch::{
    runcache, Agent, AgentConfig, DispatchOptions, Dispatcher, GcPolicy, RunCache, WorkerKind,
    WorkerPool,
};
use adpsgd::experiment::{Campaign, RunSpec};
use adpsgd::period::Strategy;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("adpsgd_it_dispatch_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn quick_base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.nodes = 2;
    cfg.iters = 60;
    cfg.batch_per_node = 8;
    cfg.eval_every = 30;
    cfg.variance_every = 20;
    cfg.workload.input_dim = 24;
    cfg.workload.hidden = 12;
    cfg.workload.eval_batches = 2;
    cfg.optim.schedule = LrSchedule::Const;
    cfg.sync.period = 4;
    cfg.sync.p_init = 2;
    cfg.sync.warmup_iters = 4;
    cfg
}

fn eight_run_campaign(base: &ExperimentConfig) -> Campaign {
    Campaign::builder("it_dispatch", base.clone())
        .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
        .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .strategy("full", StrategySpec::Full)
        .strategy("qsgd", base.sync.spec_of(Strategy::Qsgd))
        .collectives(&[adpsgd::collective::Algo::Ring, adpsgd::collective::Algo::Flat])
        .build()
        .unwrap()
}

/// The `adpsgd` binary for subprocess-worker tests (cargo builds and
/// exports it for integration tests).
fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_adpsgd"))
}

/// Full-fidelity report JSON minus the measured wall/compute clocks —
/// the determinism witness for comparing *separate executions* (cache
/// hits are bit-identical including clocks; fresh re-executions are
/// bit-identical except for them).
fn stable_report_json(r: &adpsgd::RunReport) -> String {
    use adpsgd::util::json::Json;
    let mut obj = match runcache::report_to_json(r) {
        Json::Obj(m) => m,
        _ => unreachable!("report json is an object"),
    };
    obj.remove("wall_secs");
    obj.remove("compute_secs");
    Json::Obj(obj).to_string_compact()
}

// ------------------------------------------------------------------ cache

#[test]
fn warm_campaign_does_no_training_and_summary_is_byte_identical() {
    let cache = tmpdir("warm");
    let base = quick_base();
    let opts = DispatchOptions {
        jobs: Some(4),
        cache_dir: Some(cache.clone()),
        ..DispatchOptions::default()
    };
    let cold = eight_run_campaign(&base).execute(&opts).unwrap();
    assert_eq!(cold.cache_hits(), 0);
    assert_eq!(cold.runs.len(), 8);

    let warm = eight_run_campaign(&base).execute(&opts).unwrap();
    assert_eq!(warm.cache_hits(), 8, "every run must be answered from the cache");

    // byte-identical stable summaries (what `adpsgd campaign --out` writes)
    assert_eq!(
        cold.to_json_stable().to_string_compact(),
        warm.to_json_stable().to_string_compact()
    );
    // and per-run reports are bit-identical including series and ledger
    for (a, b) in cold.runs.iter().zip(&warm.runs) {
        assert_eq!(
            runcache::report_to_json(&a.report).to_string_compact(),
            runcache::report_to_json(&b.report).to_string_compact(),
            "{}",
            a.label
        );
    }
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn cache_is_shared_across_campaign_definitions() {
    // two different campaigns containing the same resolved run share it
    let cache = tmpdir("shared");
    let base = quick_base();
    let opts = DispatchOptions {
        jobs: Some(2),
        cache_dir: Some(cache.clone()),
        ..DispatchOptions::default()
    };
    let first = Campaign::builder("one", base.clone())
        .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
        .build()
        .unwrap()
        .execute(&opts)
        .unwrap();
    assert_eq!(first.cache_hits(), 0);
    let second = Campaign::builder("two", base.clone())
        .strategy("cpsgd_again", base.sync.spec_of(Strategy::Constant))
        .strategy("full", StrategySpec::Full)
        .build()
        .unwrap()
        .execute(&opts)
        .unwrap();
    assert_eq!(second.cache_hits(), 1, "the shared run must hit; labels are incidental");
    // the hit is restamped under the requesting label
    assert_eq!(second.get("cpsgd_again").name, "cpsgd_again");
    assert_eq!(
        second.get("cpsgd_again").final_train_loss,
        first.get("cpsgd").final_train_loss
    );
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn result_affecting_knobs_bust_the_campaign_cache() {
    let cache = tmpdir("bust");
    let base = quick_base();
    let opts = DispatchOptions {
        jobs: Some(2),
        cache_dir: Some(cache.clone()),
        ..DispatchOptions::default()
    };
    let campaign = |cfg: &ExperimentConfig| {
        Campaign::builder("b", cfg.clone())
            .strategy("cpsgd", cfg.sync.spec_of(Strategy::Constant))
            .build()
            .unwrap()
    };
    campaign(&base).execute(&opts).unwrap();
    let mut reseeded = base.clone();
    reseeded.seed = 777;
    let r = campaign(&reseeded).execute(&opts).unwrap();
    assert_eq!(r.cache_hits(), 0, "a new seed is a new run");
    let mut retuned = base.clone();
    retuned.sync.period = 5;
    let r = campaign(&retuned).execute(&opts).unwrap();
    assert_eq!(r.cache_hits(), 0, "a strategy knob is part of the key");
    // but an output-only knob hits
    let mut renamed = base.clone();
    renamed.checkpoint_dir = "/somewhere/else".into();
    let r = campaign(&renamed).execute(&opts).unwrap();
    assert_eq!(r.cache_hits(), 1, "output paths are incidental");
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn every_cluster_knob_busts_the_campaign_cache() {
    // [cluster] knobs never move the trained parameters, but they do
    // move modeled clocks — which reports carry — so each one must be
    // part of the run-cache key.
    let cache = tmpdir("cluster_bust");
    let base = quick_base();
    let opts = DispatchOptions {
        jobs: Some(2),
        cache_dir: Some(cache.clone()),
        ..DispatchOptions::default()
    };
    let campaign = |cfg: &ExperimentConfig| {
        Campaign::builder("cb", cfg.clone())
            .strategy("cpsgd", cfg.sync.spec_of(Strategy::Constant))
            .build()
            .unwrap()
    };
    let seeded = campaign(&base).execute(&opts).unwrap();
    assert_eq!(seeded.cache_hits(), 0);

    // one mutation per [cluster] key (each valid for the 2-node base)
    let knobs: Vec<(&str, Box<dyn Fn(&mut ExperimentConfig)>)> = vec![
        ("cluster.skew", Box::new(|c| c.cluster.skew = "straggler:3.0".into())),
        ("cluster.factors", Box::new(|c| c.cluster.factors = vec![1.0, 2.5])),
        ("cluster.step_us", Box::new(|c| c.cluster.step_us = 2000.0)),
        ("cluster.jitter", Box::new(|c| c.cluster.jitter = 0.2)),
        ("cluster.link_bw_gbps", Box::new(|c| c.cluster.link_bw_gbps = vec![100.0, 10.0])),
        ("cluster.link_latency_us", Box::new(|c| c.cluster.link_latency_us = vec![2.0, 50.0])),
        ("cluster.faults.seed", Box::new(|c| c.cluster.faults.seed = 99)),
        ("cluster.faults.pauses", Box::new(|c| c.cluster.faults.pauses = 1)),
        ("cluster.faults.pause_secs", Box::new(|c| c.cluster.faults.pause_secs = 0.25)),
        ("cluster.faults.spikes", Box::new(|c| c.cluster.faults.spikes = 1)),
        ("cluster.faults.spike_secs", Box::new(|c| c.cluster.faults.spike_secs = 5e-3)),
        ("cluster.faults.spike_len", Box::new(|c| c.cluster.faults.spike_len = 16)),
    ];
    for (key, mutate) in &knobs {
        let mut tweaked = base.clone();
        mutate(&mut tweaked);
        let r = campaign(&tweaked).execute(&opts).unwrap();
        assert_eq!(r.cache_hits(), 0, "{key} must be part of the run-cache key");
    }
    // the untouched base still hits: the busts were the knobs, not noise
    let warm = campaign(&base).execute(&opts).unwrap();
    assert_eq!(warm.cache_hits(), 1);
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn corrupted_cache_entry_is_recomputed_not_trusted() {
    let cache = tmpdir("corrupt");
    let base = quick_base();
    let opts = DispatchOptions {
        jobs: Some(1),
        cache_dir: Some(cache.clone()),
        ..DispatchOptions::default()
    };
    let campaign = || {
        Campaign::builder("c", quick_base())
            .strategy("cpsgd", quick_base().sync.spec_of(Strategy::Constant))
            .build()
            .unwrap()
    };
    let cold = campaign().execute(&opts).unwrap();
    // trash every entry in the cache dir
    let mut entries = 0;
    for e in std::fs::read_dir(&cache).unwrap() {
        let p = e.unwrap().path();
        if p.extension().map(|x| x == "json").unwrap_or(false) {
            std::fs::write(&p, b"{\"version\":1,\"cfg_hash\":\"junk\"").unwrap();
            entries += 1;
        }
    }
    assert_eq!(entries, 1);
    let recomputed = campaign().execute(&opts).unwrap();
    assert_eq!(recomputed.cache_hits(), 0, "corrupt entries must miss");
    assert_eq!(
        recomputed.get("cpsgd").final_train_loss,
        cold.get("cpsgd").final_train_loss,
        "recompute reproduces the original"
    );
    // the rewritten entry is valid again
    let warm = campaign().execute(&opts).unwrap();
    assert_eq!(warm.cache_hits(), 1);
    let _ = base;
    std::fs::remove_dir_all(&cache).ok();
}

// ----------------------------------------------------- determinism / jobs

#[test]
fn jobs_levels_produce_identical_merged_results() {
    // the acceptance gate: jobs=4 on an 8-run campaign == jobs=1
    let base = quick_base();
    let run = |jobs: usize| {
        eight_run_campaign(&base)
            .execute(&DispatchOptions {
                jobs: Some(jobs),
                cache_dir: None,
                ..DispatchOptions::default()
            })
            .unwrap()
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial.runs.len(), 8);
    for (a, b) in serial.runs.iter().zip(&parallel.runs) {
        assert_eq!(a.label, b.label);
        assert_eq!(
            stable_report_json(&a.report),
            stable_report_json(&b.report),
            "{}: the merge must be deterministic across job counts",
            a.label
        );
    }
}

// ------------------------------------------------------------- subprocess

#[test]
fn subprocess_workers_match_thread_workers_exactly() {
    let base = quick_base();
    let campaign = Campaign::builder("sub", base.clone())
        .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
        .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .strategy("full", StrategySpec::Full)
        .build()
        .unwrap();
    let threads = campaign
        .execute(&DispatchOptions {
            jobs: Some(2),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    let subprocesses = campaign
        .execute(&DispatchOptions {
            jobs: Some(2),
            workers: WorkerKind::Subprocess,
            worker_exe: Some(worker_exe()),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    for (a, b) in threads.runs.iter().zip(&subprocesses.runs) {
        assert_eq!(a.label, b.label);
        assert_eq!(
            stable_report_json(&a.report),
            stable_report_json(&b.report),
            "{}: subprocess transport must not change results",
            a.label
        );
    }
}

#[test]
fn subprocess_run_failure_aborts_with_the_workers_message() {
    let mut bad = quick_base();
    bad.name = "boom".into();
    bad.workload.backend = adpsgd::config::Backend::Native("failing:0:5".into());
    let runs = vec![RunSpec { label: "boom".into(), cfg: bad }];
    let err = Dispatcher::new(DispatchOptions {
        jobs: Some(1),
        workers: WorkerKind::Subprocess,
        worker_exe: Some(worker_exe()),
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected failure"), "{msg}");
    assert!(msg.contains("boom"), "{msg}");
}

#[test]
fn killed_worker_is_retried_on_a_fresh_child() {
    // a long-enough run that the kill lands mid-training
    let mut cfg = quick_base();
    cfg.name = "survivor".into();
    cfg.iters = 8000;
    cfg.eval_every = 4000;
    cfg.variance_every = 0;
    let runs = vec![RunSpec { label: "survivor".into(), cfg: cfg.clone() }];

    // a private pool: the assassin must never see another test's
    // workers through the process-wide shared pool
    let dispatcher = Dispatcher::with_pool(
        DispatchOptions {
            jobs: Some(1),
            workers: WorkerKind::Subprocess,
            worker_exe: Some(worker_exe()),
            cache_dir: None,
            ..DispatchOptions::default()
        },
        Arc::new(WorkerPool::new()),
    );
    let pids = dispatcher.worker_pids();

    // assassin: kill the first worker child as soon as it appears
    let assassin = std::thread::spawn(move || {
        for _ in 0..500 {
            let victim = pids.lock().unwrap().first().copied();
            if let Some(pid) = victim {
                // the child has at most parsed the request by now — an
                // 8000-iteration run cannot have finished.  (`kill` via
                // sh: the builtin exists even without procps.)
                let _ = std::process::Command::new("sh")
                    .arg("-c")
                    .arg(format!("kill {pid}"))
                    .status();
                return Some(pid);
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        None
    });

    let merged = dispatcher.execute(&runs).expect("dispatch survives a killed worker");
    let victim = assassin.join().unwrap().expect("the assassin must have found a worker");
    assert!(dispatcher.retries() >= 1, "the kill must have caused at least one retry");
    assert_eq!(merged.len(), 1);
    assert!(!merged[0].from_cache);
    // the crash path prunes the dead child's pid immediately: no
    // observer (or assassin) can ever target it again
    assert!(
        !dispatcher.worker_pids().lock().unwrap().contains(&victim),
        "a crashed worker's pid must be pruned from the registry"
    );

    // and the retried result is exactly the undisturbed result
    let undisturbed = Dispatcher::new(DispatchOptions {
        jobs: Some(1),
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap();
    assert_eq!(
        stable_report_json(&merged[0].report),
        stable_report_json(&undisturbed[0].report),
        "a retried run must reproduce the undisturbed run bit-for-bit"
    );
}

// ------------------------------------------------------------ supervision

#[test]
fn stopped_worker_is_declared_hung_and_run_retried() {
    // a SIGSTOPped child keeps its pipe open, so EOF never comes — only
    // the heartbeat deadline can unstick the dispatch
    let mut cfg = quick_base();
    cfg.name = "frozen".into();
    cfg.iters = 8000;
    cfg.eval_every = 4000;
    cfg.variance_every = 0;
    let runs = vec![RunSpec { label: "frozen".into(), cfg: cfg.clone() }];

    let dispatcher = Dispatcher::with_pool(
        DispatchOptions {
            jobs: Some(1),
            workers: WorkerKind::Subprocess,
            worker_exe: Some(worker_exe()),
            cache_dir: None,
            heartbeat_timeout: Duration::from_millis(2000),
            ..DispatchOptions::default()
        },
        Arc::new(WorkerPool::new()),
    );
    let pids = dispatcher.worker_pids();

    // freezer: SIGSTOP the first worker child as soon as it appears
    let freezer = std::thread::spawn(move || {
        for _ in 0..500 {
            let victim = pids.lock().unwrap().first().copied();
            if let Some(pid) = victim {
                let _ = std::process::Command::new("sh")
                    .arg("-c")
                    .arg(format!("kill -STOP {pid}"))
                    .status();
                return Some(pid);
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        None
    });

    let start = std::time::Instant::now();
    let merged = dispatcher.execute(&runs).expect("dispatch recovers from a frozen worker");
    let frozen = freezer.join().unwrap().expect("the freezer must have found a worker");
    assert!(
        dispatcher.retries() >= 1,
        "the missed heartbeat deadline must surface as a crash retry"
    );
    assert!(
        !dispatcher.worker_pids().lock().unwrap().contains(&frozen),
        "the hung child must be killed and its pid pruned"
    );
    // generous sanity bound — without hang detection this blocks forever
    assert!(
        start.elapsed() < std::time::Duration::from_secs(120),
        "recovery must be deadline-driven, not luck"
    );
    assert_eq!(merged.len(), 1);
    assert!(!merged[0].from_cache);

    let undisturbed = Dispatcher::new(DispatchOptions {
        jobs: Some(1),
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap();
    assert_eq!(
        stable_report_json(&merged[0].report),
        stable_report_json(&undisturbed[0].report),
        "the retried run must reproduce the undisturbed run bit-for-bit"
    );
}

#[test]
fn stale_terminal_frames_are_discarded_not_protocol_violations() {
    // a shim worker that injects a terminal frame for an abandoned
    // request id (as a child reused after a heartbeat timeout would)
    // before handing the session to the real worker.  Under the old
    // reader this was a "protocol violation" that burned a crash retry
    // per attempt against deterministic input.
    let dir = tmpdir("stale");
    let script = dir.join("stale_worker.sh");
    std::fs::write(
        &script,
        format!(
            "#!/bin/sh\n\
             read -r line\n\
             printf '{{\"type\":\"error\",\"id\":0,\"message\":\"stale\",\"v\":5}}\\n'\n\
             {{ printf '%s\\n' \"$line\"; cat; }} | {:?} worker\n",
            worker_exe()
        ),
    )
    .unwrap();
    #[cfg(unix)]
    {
        use std::os::unix::fs::PermissionsExt;
        std::fs::set_permissions(&script, std::fs::Permissions::from_mode(0o755)).unwrap();
    }

    let mut cfg = quick_base();
    cfg.name = "stale_ok".into();
    let runs = vec![RunSpec { label: "stale_ok".into(), cfg: cfg.clone() }];
    let dispatcher = Dispatcher::with_pool(
        DispatchOptions {
            jobs: Some(1),
            workers: WorkerKind::Subprocess,
            worker_exe: Some(script.clone()),
            cache_dir: None,
            ..DispatchOptions::default()
        },
        Arc::new(WorkerPool::new()),
    );
    let merged = dispatcher.execute(&runs).expect("a stale frame must not fail the dispatch");
    assert_eq!(
        dispatcher.retries(),
        0,
        "a stale terminal frame must be discarded, not misread as a crash"
    );
    assert_eq!(merged.len(), 1);

    let undisturbed = Dispatcher::new(DispatchOptions {
        jobs: Some(1),
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap();
    assert_eq!(
        stable_report_json(&merged[0].report),
        stable_report_json(&undisturbed[0].report),
        "the run served after a stale frame must be bit-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------- remote agents

/// Spawn an in-process loopback agent on a private pool.  The worker
/// children must come from the real `adpsgd` binary (this test
/// executable has no `worker` subcommand).
fn spawn_agent(slots: usize, token: Option<&str>, cache_dir: Option<PathBuf>) -> String {
    let cfg = AgentConfig {
        listen: "127.0.0.1:0".into(),
        slots,
        token: token.map(String::from),
        cache_dir,
        worker_exe: Some(worker_exe()),
        ..AgentConfig::default()
    };
    Agent::spawn(cfg, Arc::new(WorkerPool::new())).expect("loopback agent binds").to_string()
}

fn three_run_campaign(base: &ExperimentConfig) -> Campaign {
    Campaign::builder("remote", base.clone())
        .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
        .strategy("adpsgd", base.sync.spec_of(Strategy::Adaptive))
        .strategy("full", StrategySpec::Full)
        .build()
        .unwrap()
}

#[test]
fn remote_agent_matches_thread_workers_bit_identically() {
    let base = quick_base();
    let addr = spawn_agent(2, None, None);
    let threads = three_run_campaign(&base)
        .execute(&DispatchOptions {
            jobs: Some(2),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    let remote = three_run_campaign(&base)
        .execute(&DispatchOptions {
            workers: WorkerKind::Remote,
            remote: vec![addr],
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    for (a, b) in threads.runs.iter().zip(&remote.runs) {
        assert_eq!(a.label, b.label);
        assert!(!b.from_cache, "no dispatcher cache was configured");
        assert_eq!(
            stable_report_json(&a.report),
            stable_report_json(&b.report),
            "{}: the TCP transport must not change results",
            a.label
        );
    }
    // the acceptance gate: the stable summary (what `adpsgd campaign
    // --out` writes) is byte-identical across local and remote
    assert_eq!(
        threads.to_json_stable().to_string_compact(),
        remote.to_json_stable().to_string_compact(),
        "remote campaign must write a byte-identical stable summary"
    );
}

#[test]
fn warm_agent_answers_from_its_own_cache() {
    let agent_cache = tmpdir("agent_cache");
    let base = quick_base();
    let addr = spawn_agent(2, None, Some(agent_cache.clone()));
    // no dispatcher-side cache: every probe happens on the agent
    let opts = DispatchOptions {
        workers: WorkerKind::Remote,
        remote: vec![addr],
        cache_dir: None,
        ..DispatchOptions::default()
    };
    let cold = three_run_campaign(&base).execute(&opts).unwrap();
    let entries = std::fs::read_dir(&agent_cache)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().ends_with(".run.json")
        })
        .count();
    assert_eq!(entries, 3, "the agent must populate its own cache");
    let warm = three_run_campaign(&base).execute(&opts).unwrap();
    // an agent cache hit reproduces the original report bit-for-bit —
    // *including* the measured clocks, which fresh executions cannot
    for (a, b) in cold.runs.iter().zip(&warm.runs) {
        assert_eq!(
            runcache::report_to_json(&a.report).to_string_compact(),
            runcache::report_to_json(&b.report).to_string_compact(),
            "{}: a warm agent must answer from its cache, not recompute",
            a.label
        );
    }
    std::fs::remove_dir_all(&agent_cache).ok();
}

#[test]
fn wrong_token_and_version_skew_are_rejected_with_clear_errors() {
    let base = quick_base();
    let runs = vec![RunSpec { label: "r".into(), cfg: base.clone() }];
    // wrong token
    let addr = spawn_agent(1, Some("sesame"), None);
    let err = Dispatcher::new(DispatchOptions {
        workers: WorkerKind::Remote,
        remote: vec![addr.clone()],
        remote_token: Some("wrong".into()),
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("token"), "{msg}");
    // missing token against a token-requiring agent
    let err = Dispatcher::new(DispatchOptions {
        workers: WorkerKind::Remote,
        remote: vec![addr],
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap_err();
    assert!(format!("{err:#}").contains("token"), "{err:#}");

    // version skew: a fake agent that opens the handshake with a v1
    // frame must be diagnosed as skew, not a generic parse failure.
    // (The real agent speaks first — it sends the challenge — so the
    // fake writes its skewed frame immediately on accept.)
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let skew_addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        if let Ok((mut s, _)) = listener.accept() {
            use std::io::Write;
            let payload = b"{\"type\":\"challenge\",\"nonce\":\"n\",\"v\":1}";
            let mut buf = (payload.len() as u32).to_be_bytes().to_vec();
            buf.extend_from_slice(payload);
            let _ = s.write_all(&buf);
            std::thread::sleep(Duration::from_millis(200));
        }
    });
    let err = Dispatcher::new(DispatchOptions {
        workers: WorkerKind::Remote,
        remote: vec![skew_addr],
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("protocol version skew"), "{msg}");

    // and remote-only with no endpoints is a configuration error
    let err = Dispatcher::new(DispatchOptions {
        workers: WorkerKind::Remote,
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap_err();
    assert!(format!("{err:#}").contains("--remote"), "{err:#}");
}

#[test]
fn mixed_local_and_remote_dispatch_is_deterministic() {
    let base = quick_base();
    let addr = spawn_agent(2, None, None);
    let local = eight_run_campaign(&base)
        .execute(&DispatchOptions {
            jobs: Some(2),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    for jobs in [1usize, 4] {
        let mixed = eight_run_campaign(&base)
            .execute(&DispatchOptions {
                jobs: Some(jobs),
                workers: WorkerKind::Thread,
                remote: vec![addr.clone()],
                cache_dir: None,
                ..DispatchOptions::default()
            })
            .unwrap();
        assert_eq!(mixed.runs.len(), 8);
        for (a, b) in local.runs.iter().zip(&mixed.runs) {
            assert_eq!(a.label, b.label);
            assert_eq!(
                stable_report_json(&a.report),
                stable_report_json(&b.report),
                "{} (jobs {jobs}): mixed local+remote must merge deterministically",
                a.label
            );
        }
        assert_eq!(
            local.to_json_stable().to_string_compact(),
            mixed.to_json_stable().to_string_compact(),
            "jobs {jobs}: stable summaries must be byte-identical"
        );
    }
}

#[test]
fn agent_killed_mid_campaign_requeues_onto_remaining_slots() {
    use std::io::BufRead;
    // a real `adpsgd agent` subprocess, so it can be killed mid-run
    let mut agent = std::process::Command::new(worker_exe())
        .args(["agent", "--listen", "127.0.0.1:0", "--slots", "2"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning adpsgd agent");
    let stdout = agent.stdout.take().expect("piped agent stdout");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let (start_tx, start_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        for line in std::io::BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if let Some(rest) = line.strip_prefix("agent: listening on ") {
                let addr = rest.split_whitespace().next().unwrap_or("").to_string();
                let _ = addr_tx.send(addr);
            }
            if line.contains("started") {
                let _ = start_tx.send(());
            }
        }
    });
    let addr = addr_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("agent must announce its address");

    // long runs so the kill lands mid-training
    let mut cfg = quick_base();
    cfg.iters = 8000;
    cfg.eval_every = 4000;
    cfg.variance_every = 0;
    let mk = |name: &str, seed: u64| {
        let mut c = cfg.clone();
        c.name = name.into();
        c.seed = seed;
        RunSpec { label: name.into(), cfg: c }
    };
    let runs = vec![mk("ra", 11), mk("rb", 22), mk("rc", 33)];

    // mixed pool: one local thread slot plus the agent's two slots
    let dispatcher = Dispatcher::new(DispatchOptions {
        jobs: Some(1),
        workers: WorkerKind::Thread,
        remote: vec![addr],
        cache_dir: None,
        heartbeat_timeout: Duration::from_secs(10),
        ..DispatchOptions::default()
    });

    // assassin: kill the agent as soon as it starts executing a run
    let agent_pid = agent.id();
    let killer = std::thread::spawn(move || {
        let seen = start_rx.recv_timeout(Duration::from_secs(60)).is_ok();
        let _ = std::process::Command::new("sh")
            .arg("-c")
            .arg(format!("kill {agent_pid}"))
            .status();
        seen
    });

    let merged = dispatcher.execute(&runs).expect("dispatch survives a killed agent");
    assert!(killer.join().unwrap(), "the agent must have started at least one run");
    assert!(
        dispatcher.retries() >= 1,
        "killing the agent mid-run must requeue through the crash path"
    );
    agent.wait().ok();

    // the requeued runs still produce exactly the undisturbed results
    let undisturbed = Dispatcher::new(DispatchOptions {
        jobs: Some(2),
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap();
    assert_eq!(merged.len(), undisturbed.len());
    for (a, b) in merged.iter().zip(&undisturbed) {
        assert_eq!(
            stable_report_json(&a.report),
            stable_report_json(&b.report),
            "a run requeued off a dead agent must reproduce the undisturbed run bit-for-bit"
        );
    }
}

// ------------------------------------------------------------------ fleet

/// Reserve a loopback port by binding and immediately dropping the
/// listener (Rust's std sets SO_REUSEADDR on Unix, so a restarted
/// daemon can rebind the same address right away).
fn reserve_port() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    format!("127.0.0.1:{}", l.local_addr().unwrap().port())
}

/// Spawn a real `adpsgd agent` daemon on `addr`, wait until it
/// listens, and return the child plus a channel that fires whenever
/// the daemon logs a run start.
fn spawn_agent_daemon(addr: &str) -> (std::process::Child, std::sync::mpsc::Receiver<()>) {
    use std::io::BufRead;
    let mut agent = std::process::Command::new(worker_exe())
        .args(["agent", "--listen", addr, "--slots", "2"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning adpsgd agent");
    let stdout = agent.stdout.take().expect("piped agent stdout");
    let (listen_tx, listen_rx) = std::sync::mpsc::channel();
    let (start_tx, start_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        for line in std::io::BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if line.starts_with("agent: listening on ") {
                let _ = listen_tx.send(());
            }
            if line.contains("started") {
                let _ = start_tx.send(());
            }
        }
    });
    listen_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("agent daemon must come up");
    (agent, start_rx)
}

#[test]
fn restarted_agent_is_redialed_and_the_campaign_completes() {
    let addr = reserve_port();
    let (mut first, start_rx) = spawn_agent_daemon(&addr);

    // long runs so the restart lands mid-training
    let mut cfg = quick_base();
    cfg.iters = 8000;
    cfg.eval_every = 4000;
    cfg.variance_every = 0;
    let mk = |name: &str, seed: u64| {
        let mut c = cfg.clone();
        c.name = name.into();
        c.seed = seed;
        RunSpec { label: name.into(), cfg: c }
    };
    let runs = vec![mk("fa", 41), mk("fb", 42), mk("fc", 43)];

    // remote-only: the restarted daemon is the *only* capacity, so the
    // campaign can finish only if the redial actually reconnects
    let dispatcher = Dispatcher::new(DispatchOptions {
        workers: WorkerKind::Remote,
        remote: vec![addr.clone()],
        cache_dir: None,
        heartbeat_timeout: Duration::from_secs(10),
        ..DispatchOptions::default()
    });

    // restarter: once a run is executing, kill the daemon and bring a
    // fresh one up on the same address
    let first_pid = first.id();
    let restart_addr = addr.clone();
    let restarter = std::thread::spawn(move || {
        let seen = start_rx.recv_timeout(Duration::from_secs(60)).is_ok();
        let _ = std::process::Command::new("sh")
            .arg("-c")
            .arg(format!("kill {first_pid}"))
            .status();
        let replacement = spawn_agent_daemon(&restart_addr).0;
        (seen, replacement)
    });

    let merged = dispatcher.execute(&runs).expect("dispatch survives an agent restart");
    let (seen, mut second) = restarter.join().unwrap();
    assert!(seen, "the daemon must have started at least one run before the restart");
    assert!(
        dispatcher.retries() >= 1,
        "the dropped connection must requeue in-flight runs through the crash path"
    );
    first.wait().ok();
    second.kill().ok();
    second.wait().ok();

    // redriven runs still produce exactly the undisturbed results
    let undisturbed = Dispatcher::new(DispatchOptions {
        jobs: Some(2),
        cache_dir: None,
        ..DispatchOptions::default()
    })
    .execute(&runs)
    .unwrap();
    assert_eq!(merged.len(), undisturbed.len());
    for (a, b) in merged.iter().zip(&undisturbed) {
        assert_eq!(
            stable_report_json(&a.report),
            stable_report_json(&b.report),
            "a run redriven after the restart must reproduce the undisturbed run bit-for-bit"
        );
    }
}

/// Spawn a real `adpsgd agent` daemon on `addr` with its stdout teed to
/// `log`, and wait until it announces its listen address.
fn spawn_agent_daemon_logged(addr: &str, log: &std::path::Path) -> std::process::Child {
    let out = std::fs::File::create(log).unwrap();
    let child = std::process::Command::new(worker_exe())
        .args(["agent", "--listen", addr, "--slots", "2"])
        .stdout(std::process::Stdio::from(out))
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning adpsgd agent");
    for _ in 0..150 {
        if std::fs::read_to_string(log)
            .map(|s| s.contains("agent: listening on"))
            .unwrap_or(false)
        {
            return child;
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    panic!("agent daemon must come up");
}

#[test]
fn trace_id_follows_a_remote_run_across_journal_agent_and_cache() {
    use adpsgd::util::json::Json;
    let dir = tmpdir("trace");
    let agent_log = dir.join("agent.log");
    let addr = reserve_port();
    let mut agent = spawn_agent_daemon_logged(&addr, &agent_log);

    let cache_dir = dir.join("cache");
    let journal_path = dir.join("trace.campaign.jsonl");
    let base = quick_base();
    let journaled = three_run_campaign(&base)
        .execute(&DispatchOptions {
            workers: WorkerKind::Remote,
            remote: vec![addr.clone()],
            cache_dir: Some(cache_dir.clone()),
            journal: Some(adpsgd::obs::Journal::create(&journal_path).unwrap()),
            ..DispatchOptions::default()
        })
        .expect("journaled remote campaign");
    assert_eq!(journaled.runs.len(), 3);
    agent.kill().ok();
    agent.wait().ok();

    // every line parses under the versioned schema, and the campaign
    // brackets are present
    let lines = adpsgd::obs::journal::read_all(&journal_path).expect("journal parses");
    let events: Vec<&str> =
        lines.iter().filter_map(|l| l.get("event").and_then(Json::as_str)).collect();
    assert_eq!(events.len(), lines.len(), "every line carries an event");
    assert_eq!(events.first(), Some(&"campaign.start"));
    assert_eq!(events.last(), Some(&"campaign.end"));

    // leg 1: the driver journaled a remote run.start with a trace id
    let start = lines
        .iter()
        .find(|l| {
            l.get("event").and_then(Json::as_str) == Some("run.start")
                && l.get("slot")
                    .and_then(Json::as_str)
                    .is_some_and(|s| s.starts_with("remote:"))
        })
        .expect("a remote run.start must be journaled");
    let trace =
        start.get("trace").and_then(Json::as_str).expect("run.start carries a trace").to_string();

    // leg 2: the agent logged its handling of the SAME trace (the v5
    // RunRequest frame carried it across the TCP hop)
    let agent_out = std::fs::read_to_string(&agent_log).unwrap();
    assert!(
        agent_out.contains(&trace),
        "agent-side handling must name trace {trace}:\n{agent_out}"
    );

    // leg 3: the cache.store journaled under the same trace names the
    // digest of the cached RunReport actually sitting on disk
    let store = lines
        .iter()
        .find(|l| {
            l.get("event").and_then(Json::as_str) == Some("cache.store")
                && l.get("trace").and_then(Json::as_str) == Some(trace.as_str())
        })
        .expect("the traced run's cache.store must be journaled");
    let digest = store.get("digest").and_then(Json::as_str).unwrap();
    let cached = cache_dir.join(format!("{digest}.run.json"));
    assert!(cached.is_file(), "cached RunReport {} must exist", cached.display());

    // and journaling must be a pure observer: the stable summary is
    // byte-identical with the journal on or off (thread workers attach
    // the full per-event JournalObserver stream — the strongest case)
    let onoff_path = dir.join("onoff.campaign.jsonl");
    let on = three_run_campaign(&base)
        .execute(&DispatchOptions {
            jobs: Some(2),
            cache_dir: None,
            journal: Some(adpsgd::obs::Journal::create(&onoff_path).unwrap()),
            ..DispatchOptions::default()
        })
        .unwrap();
    let off = three_run_campaign(&base)
        .execute(&DispatchOptions {
            jobs: Some(2),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    assert_eq!(
        on.to_json_stable().to_string_compact(),
        off.to_json_stable().to_string_compact(),
        "the stable summary must not change when journaling is enabled"
    );
    // the detailed stream really was captured for in-process runs
    let on_lines = adpsgd::obs::journal::read_all(&onoff_path).unwrap();
    assert!(
        on_lines.iter().any(|l| l.get("event").and_then(Json::as_str) == Some("run.sync")),
        "thread workers must journal the typed event stream"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streamed_events_carry_one_trace_across_all_four_legs() {
    use adpsgd::util::json::Json;
    let dir = tmpdir("stream_legs");
    let base = quick_base();

    // follow one run: driver journal → worker child → (remote agent) →
    // merged journal line tagged with its origin.  First the stdio leg:
    // subprocess children render the observer lines themselves
    // (StreamObserver) and the driver merges them with origin "node".
    let sub_path = dir.join("sub.campaign.jsonl");
    three_run_campaign(&base)
        .execute(&DispatchOptions {
            jobs: Some(2),
            workers: WorkerKind::Subprocess,
            worker_exe: Some(worker_exe()),
            cache_dir: None,
            journal: Some(adpsgd::obs::Journal::create(&sub_path).unwrap()),
            ..DispatchOptions::default()
        })
        .expect("journaled subprocess campaign");
    let lines = adpsgd::obs::journal::read_all(&sub_path).expect("merged journal parses");
    let ev = |l: &Json| l.get("event").and_then(Json::as_str).unwrap_or("").to_string();
    let origin = |l: &Json| l.get("origin").and_then(Json::as_str).map(str::to_string);
    let trace_of = |l: &Json| l.get("trace").and_then(Json::as_str).unwrap().to_string();

    // leg 1: the driver's own lifecycle line, no origin
    let queued = lines
        .iter()
        .find(|l| ev(l) == "run.queued")
        .expect("driver journals run.queued");
    assert_eq!(origin(queued), None, "driver-side lines carry no origin");
    let trace = trace_of(queued);
    // legs 2+4: the worker child rendered typed coordinator events for
    // the SAME trace, and they merged back tagged origin "node"
    for event in ["run.sync", "run.end"] {
        let streamed = lines
            .iter()
            .find(|l| ev(l) == event && trace_of(l) == trace)
            .unwrap_or_else(|| panic!("{event} must be streamed for trace {trace}"));
        assert_eq!(origin(streamed).as_deref(), Some("node"), "{event}");
    }
    // the driver's terminal line closes the same trace, unmerged
    let done = lines
        .iter()
        .find(|l| ev(l) == "run.done" && trace_of(l) == trace)
        .expect("run.done under the same trace");
    assert_eq!(origin(done), None);

    // leg 3: over TCP — a loopback agent relays its worker child's
    // events interleaved with heartbeats; merged origin is the agent
    let addr = spawn_agent(2, None, None);
    let rem_path = dir.join("rem.campaign.jsonl");
    three_run_campaign(&base)
        .execute(&DispatchOptions {
            workers: WorkerKind::Remote,
            remote: vec![addr.clone()],
            cache_dir: None,
            journal: Some(adpsgd::obs::Journal::create(&rem_path).unwrap()),
            ..DispatchOptions::default()
        })
        .expect("journaled remote campaign");
    let lines = adpsgd::obs::journal::read_all(&rem_path).unwrap();
    let start = lines
        .iter()
        .find(|l| {
            ev(l) == "run.start"
                && l.get("slot").and_then(Json::as_str).is_some_and(|s| s.starts_with("remote:"))
        })
        .expect("a remote run.start must be journaled");
    let trace = trace_of(start);
    let agent_origin = format!("agent:{addr}");
    for event in ["run.sync", "run.end"] {
        let streamed = lines
            .iter()
            .find(|l| ev(l) == event && trace_of(l) == trace)
            .unwrap_or_else(|| panic!("{event} must be relayed for trace {trace}"));
        assert_eq!(origin(streamed).as_deref(), Some(agent_origin.as_str()), "{event}");
    }

    // and the merged journal is exactly what `adpsgd trace` consumes:
    // every run reconstructs with a full per-node attribution whose
    // books close against the run.done wall clock
    let report = adpsgd::obs::trace::analyze_file(&rem_path).expect("trace analysis");
    assert_eq!(report.runs.len(), 3);
    for run in &report.runs {
        assert!(run.attributed(), "{}: needs streamed run.sync/run.end", run.label);
        assert_eq!(run.nodes, base.nodes);
        assert_eq!(run.origins, vec![agent_origin.clone()]);
        let done = lines
            .iter()
            .find(|l| ev(l) == "run.done" && trace_of(l) == run.trace.clone().unwrap())
            .unwrap();
        let wall = done.get("modeled_wall_secs").and_then(Json::as_f64).unwrap();
        assert!(
            (run.modeled_wall_secs - wall).abs() < 1e-9,
            "{}: reconstructed wall {} vs dispatched {wall}",
            run.label,
            run.modeled_wall_secs
        );
    }
    // the harvested skew block round-trips through the config parser
    let block = report.emit_cluster().expect("emit-cluster");
    assert!(block.starts_with("[cluster]\nfactors = ["), "{block}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn event_streaming_never_changes_the_stable_summary() {
    use adpsgd::util::json::Json;
    let dir = tmpdir("stream_onoff");
    let base = quick_base();
    let agent_addr = spawn_agent(2, None, None);
    // property: for every executor the stable summary is byte-identical
    // with event streaming on or off — streaming is a pure observer
    let execute = |tag: &str, workers: WorkerKind, stream: bool| {
        let journal_path = dir.join(format!("{tag}.campaign.jsonl"));
        let report = three_run_campaign(&base)
            .execute(&DispatchOptions {
                jobs: Some(2),
                workers,
                worker_exe: matches!(workers, WorkerKind::Subprocess)
                    .then(worker_exe),
                remote: match workers {
                    WorkerKind::Remote => vec![agent_addr.clone()],
                    _ => vec![],
                },
                cache_dir: None,
                journal: Some(adpsgd::obs::Journal::create(&journal_path).unwrap()),
                stream_events: stream,
                ..DispatchOptions::default()
            })
            .unwrap_or_else(|e| panic!("{tag}: {e:#}"));
        let streamed = adpsgd::obs::journal::read_all(&journal_path)
            .unwrap()
            .iter()
            .any(|l| l.get("event").and_then(Json::as_str) == Some("run.sync"));
        (report.to_json_stable().to_string_compact(), streamed)
    };
    let mut summaries = Vec::new();
    for (tag, workers) in [
        ("thread", WorkerKind::Thread),
        ("sub", WorkerKind::Subprocess),
        ("remote", WorkerKind::Remote),
    ] {
        let (on, on_streamed) = execute(&format!("{tag}_on"), workers, true);
        let (off, off_streamed) = execute(&format!("{tag}_off"), workers, false);
        assert_eq!(on, off, "{tag}: streaming must not change the stable summary");
        assert!(on_streamed, "{tag}: typed events must reach the journal when on");
        assert!(!off_streamed, "{tag}: no typed events when streaming is off");
        summaries.push(on);
    }
    assert!(
        summaries.windows(2).all(|w| w[0] == w[1]),
        "every executor must produce one identical stable summary"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fleet_member_joining_late_is_discovered_and_serves_the_campaign() {
    use adpsgd::dispatch::Registry;
    let registry = Registry::spawn("127.0.0.1:0").expect("registry binds").to_string();
    let base = quick_base();

    // the only capacity announces itself ~1.5s *after* the dispatch
    // starts polling: elastic membership must pick it up mid-campaign
    let reg = registry.clone();
    let joiner = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(1500));
        let cfg = AgentConfig {
            listen: "127.0.0.1:0".into(),
            slots: 2,
            worker_exe: Some(worker_exe()),
            fleet: Some(reg),
            ..AgentConfig::default()
        };
        Agent::spawn(cfg, Arc::new(WorkerPool::new())).expect("fleet agent binds")
    });

    let fleet = three_run_campaign(&base)
        .execute(&DispatchOptions {
            workers: WorkerKind::Remote,
            fleet: Some(registry),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .expect("a late-joining member must serve the whole campaign");
    joiner.join().unwrap();

    let local = three_run_campaign(&base)
        .execute(&DispatchOptions {
            jobs: Some(2),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    assert_eq!(fleet.runs.len(), 3);
    assert!(fleet.runs.iter().all(|r| !r.from_cache), "no dispatcher cache was configured");
    assert_eq!(
        local.to_json_stable().to_string_compact(),
        fleet.to_json_stable().to_string_compact(),
        "a fleet-resolved campaign must write a byte-identical stable summary"
    );
}

#[test]
fn warm_start_snapshot_is_staged_to_an_agent_that_lacks_it() {
    let ckpt_dir = tmpdir("blob_src");
    let agent_cache = tmpdir("blob_agent");

    // produce the snapshot locally
    let mut seed_cfg = quick_base();
    seed_cfg.name = "seed".into();
    seed_cfg.checkpoint_every = 30;
    seed_cfg.checkpoint_dir = ckpt_dir.to_string_lossy().into_owned();
    adpsgd::experiment::Experiment::from_config(seed_cfg)
        .unwrap()
        .run()
        .expect("seeding run");
    let snapshot = adpsgd::checkpoint::Checkpoint::latest(&ckpt_dir)
        .unwrap()
        .expect("the seeding run must write a snapshot");
    let digest = runcache::content_digest(&std::fs::read(&snapshot).unwrap());

    // warm-started campaign, remote-only, against an agent whose blob
    // store has never seen the snapshot: the dispatcher must stage it
    let mut base = quick_base();
    base.init_from = ckpt_dir.to_string_lossy().into_owned();
    let addr = spawn_agent(2, None, Some(agent_cache.clone()));
    let remote = three_run_campaign(&base)
        .execute(&DispatchOptions {
            workers: WorkerKind::Remote,
            remote: vec![addr],
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .expect("warm-start runs must succeed on an agent lacking the snapshot");

    // the artifact landed in the agent's content-addressed store ...
    let blob = agent_cache.join("blobs").join(format!("{digest}.blob"));
    assert!(blob.exists(), "the staged snapshot must land as {digest}.blob");
    assert_eq!(
        runcache::content_digest(&std::fs::read(&blob).unwrap()),
        digest,
        "the staged bytes must verify against their digest"
    );

    // ... and warm-starting over the wire changes nothing about results
    let local = three_run_campaign(&base)
        .execute(&DispatchOptions {
            jobs: Some(2),
            cache_dir: None,
            ..DispatchOptions::default()
        })
        .unwrap();
    assert_eq!(
        local.to_json_stable().to_string_compact(),
        remote.to_json_stable().to_string_compact(),
        "blob-staged warm starts must be byte-identical to local warm starts"
    );
    std::fs::remove_dir_all(&ckpt_dir).ok();
    std::fs::remove_dir_all(&agent_cache).ok();
}

#[test]
fn cancel_frame_kills_the_orphaned_run_in_the_agents_worker_child() {
    use adpsgd::dispatch::net::transport::{read_frame, write_frame};
    use adpsgd::dispatch::proto::{auth_proof, Frame};

    let addr = spawn_agent(1, None, None);
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = stream.try_clone().unwrap();
    let mut writer = stream;

    // handshake: challenge → proof (tokenless agent: empty token) → ack
    let nonce = match read_frame(&mut reader).unwrap() {
        Some(Frame::Challenge { nonce }) => nonce,
        other => panic!("expected a challenge, got {other:?}"),
    };
    write_frame(&mut writer, &Frame::Hello { proof: auth_proof(&nonce, "") }).unwrap();
    match read_frame(&mut reader).unwrap() {
        Some(Frame::HelloAck { .. }) => {}
        other => panic!("expected an ack, got {other:?}"),
    }

    // a run far too long to finish on its own within this test
    let mut cfg = quick_base();
    cfg.name = "orphan".into();
    cfg.iters = 2_000_000;
    cfg.eval_every = 1_000_000;
    cfg.variance_every = 0;
    write_frame(&mut writer, &Frame::RunRequest { id: 7, cfg, trace: None, stream: false })
        .unwrap();

    // the first heartbeat proves the child is training; then cancel
    loop {
        match read_frame(&mut reader).unwrap() {
            Some(Frame::Heartbeat { .. }) => break,
            Some(Frame::RunResult { .. }) => panic!("the run must still be training"),
            Some(other) => panic!("unexpected {} frame", other.kind()),
            None => panic!("agent closed the connection"),
        }
    }
    write_frame(&mut writer, &Frame::Cancel { id: 7 }).unwrap();

    // the agent kills the worker child: the run terminates as a crash
    // frame for our id long before 2M iterations could ever complete
    let cancelled_at = std::time::Instant::now();
    loop {
        match read_frame(&mut reader).unwrap() {
            Some(Frame::Heartbeat { .. }) => continue,
            Some(Frame::Crashed { id, .. }) => {
                assert_eq!(id, 7);
                break;
            }
            Some(Frame::RunResult { .. }) => panic!("a cancelled run must never complete"),
            Some(other) => panic!("unexpected {} frame", other.kind()),
            None => panic!("agent closed the connection before the terminal frame"),
        }
    }
    assert!(
        cancelled_at.elapsed() < Duration::from_secs(30),
        "cancellation must be prompt, not the run timing out"
    );
}

// ------------------------------------------------------------------- gc

#[test]
fn run_cache_gc_bounds_size_sweeps_tmp_and_survivors_still_hit() {
    let cache_dir = tmpdir("gc");
    let base = quick_base();
    let opts = DispatchOptions {
        jobs: Some(2),
        cache_dir: Some(cache_dir.clone()),
        ..DispatchOptions::default()
    };
    let campaign = || {
        Campaign::builder("gc", base.clone())
            .strategy("cpsgd", base.sync.spec_of(Strategy::Constant))
            .strategy("full", StrategySpec::Full)
            .build()
            .unwrap()
    };
    campaign().execute(&opts).unwrap();
    let entry_bytes: Vec<u64> = std::fs::read_dir(&cache_dir)
        .unwrap()
        .filter_map(|e| {
            let e = e.unwrap();
            e.file_name()
                .to_string_lossy()
                .ends_with(".run.json")
                .then(|| e.metadata().unwrap().len())
        })
        .collect();
    assert_eq!(entry_bytes.len(), 2, "both runs must be cached");
    // an orphaned temp file, as left by a writer that died mid-publish
    let orphan = cache_dir.join(".feedface.999.0.tmp");
    std::fs::write(&orphan, b"half-written").unwrap();

    // room for exactly the largest single entry: the older one goes
    let max = *entry_bytes.iter().max().unwrap();
    let cache = RunCache::new(&cache_dir);
    let stats = cache
        .gc(&GcPolicy {
            max_bytes: Some(max),
            tmp_grace: Duration::ZERO,
            ..GcPolicy::default()
        })
        .unwrap();
    assert_eq!((stats.scanned, stats.evicted, stats.kept), (2, 1, 1), "{stats:?}");
    assert!(stats.kept_bytes <= max, "{stats:?}");
    assert_eq!(stats.tmp_swept, 1, "{stats:?}");
    assert!(!orphan.exists());

    // the survivor still hits; the evicted run recomputes (and re-caches)
    let warm = campaign().execute(&opts).unwrap();
    assert_eq!(warm.cache_hits(), 1, "exactly the surviving entry must hit");

    // age-based eviction clears everything that remains
    let stats = cache
        .gc(&GcPolicy { max_age: Some(Duration::ZERO), ..GcPolicy::default() })
        .unwrap();
    assert_eq!(stats.evicted, stats.scanned, "{stats:?}");
    let cold = campaign().execute(&opts).unwrap();
    assert_eq!(cold.cache_hits(), 0, "an emptied cache recomputes everything");
    std::fs::remove_dir_all(&cache_dir).ok();
}
