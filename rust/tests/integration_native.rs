//! Integration tests over the native (pure-rust) training path: the full
//! coordinator — worker threads, collectives, period control, ledger —
//! on every strategy, asserting the paper's qualitative claims at quick
//! scale.

use adpsgd::config::{Backend, ExperimentConfig, LrSchedule};
use adpsgd::experiment::Experiment;
use adpsgd::netsim::{CommKind, NetModel};
use adpsgd::period::Strategy;

fn base(iters: usize, nodes: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.nodes = nodes;
    cfg.iters = iters;
    cfg.batch_per_node = 16;
    cfg.eval_every = iters / 4;
    cfg.workload.backend = Backend::Native("mlp".into());
    cfg.workload.input_dim = 48;
    cfg.workload.hidden = 24;
    cfg.workload.eval_batches = 6;
    cfg.optim.lr0 = 0.1;
    cfg.optim.schedule =
        LrSchedule::StepDecay { boundaries: vec![iters / 2, 3 * iters / 4], factor: 0.1 };
    cfg.sync.warmup_iters = iters / 50;
    cfg.sync.p_init = 3;
    cfg
}

fn run(cfg: ExperimentConfig) -> adpsgd::coordinator::RunReport {
    Experiment::from_config(cfg).unwrap().run().unwrap()
}

#[test]
fn all_strategies_learn_the_task() {
    for strategy in [
        Strategy::Full,
        Strategy::Constant,
        Strategy::Adaptive,
        Strategy::Decreasing,
        Strategy::Qsgd,
    ] {
        let mut cfg = base(300, 4);
        cfg.sync.strategy = strategy;
        let r = run(cfg);
        assert!(
            r.best_eval_acc > 0.6,
            "{strategy}: acc {} loss {}",
            r.best_eval_acc,
            r.final_train_loss
        );
        assert!(r.final_train_loss < 1.5, "{strategy}: loss {}", r.final_train_loss);
    }
}

#[test]
fn adpsgd_budget_beats_cpsgd_variance() {
    // the paper's core claim at matched-or-less communication
    let mut acfg = base(600, 8);
    acfg.variance_every = 5;
    acfg.sync.strategy = Strategy::Adaptive;
    let adp = run(acfg);

    let mut ccfg = base(600, 8);
    ccfg.variance_every = 5;
    ccfg.sync.strategy = Strategy::Constant;
    ccfg.sync.period = 8;
    ccfg.sync.warmup_iters = 0;
    let cps = run(ccfg);

    let avar = adp.recorder.get("var").unwrap();
    let cvar = cps.recorder.get("var").unwrap();
    // weighted-average variance (9): ADPSGD should be smaller overall
    let a_mean = avar.mean_y_in(0.0, 600.0).unwrap();
    let c_mean = cvar.mean_y_in(0.0, 600.0).unwrap();
    assert!(a_mean < c_mean, "ADPSGD mean var {a_mean:.3e} vs CPSGD {c_mean:.3e}");
}

#[test]
fn qsgd_quarter_bytes_of_fullsgd() {
    let mut fcfg = base(200, 4);
    fcfg.sync.strategy = Strategy::Full;
    let full = run(fcfg);
    let mut qcfg = base(200, 4);
    qcfg.sync.strategy = Strategy::Qsgd;
    let qsgd = run(qcfg);
    let ratio =
        full.ledger.total_wire_bytes() as f64 / qsgd.ledger.total_wire_bytes() as f64;
    // paper: 8-bit QSGD = 1/4 the data — allgather vs allreduce wire
    // accounting makes the realized ratio ~2-4x depending on n
    assert!(ratio > 1.5, "full/qsgd byte ratio {ratio}");
}

#[test]
fn warmup_epoch_syncs_every_iteration() {
    let mut cfg = base(120, 4);
    cfg.sync.strategy = Strategy::Adaptive;
    cfg.sync.warmup_iters = 30;
    let r = run(cfg);
    let syncs = r.recorder.get("sync_at").unwrap();
    let in_warmup = syncs.points.iter().filter(|p| p.0 < 30.0).count();
    assert_eq!(in_warmup, 30, "warmup must sync at every iteration");
}

#[test]
fn ledger_consistency_across_strategies() {
    for (strategy, kind) in [
        (Strategy::Full, CommKind::GradAllreduce),
        (Strategy::Constant, CommKind::ParamAvg),
        (Strategy::Adaptive, CommKind::ParamAvg),
        (Strategy::Qsgd, CommKind::QuantAllgather),
    ] {
        let mut cfg = base(150, 4);
        cfg.sync.strategy = strategy;
        let r = run(cfg);
        assert_eq!(r.ledger.count(kind), r.syncs, "{strategy}: ledger/sync mismatch");
        assert!(r.ledger.bytes(kind) > 0);
        assert!(r.ledger.secs(kind) > 0.0);
        // re-pricing under a slower net increases modeled time
        let fast = NetModel::infiniband_100g();
        let slow = NetModel::ethernet_10g();
        assert!(r.ledger.modeled_secs(&slow) > r.ledger.modeled_secs(&fast), "{strategy}");
    }
}

#[test]
fn variance_instrumentation_not_charged() {
    // same run with and without variance probes must have identical
    // communication ledgers (probes are measurement, not algorithm)
    let mut c1 = base(150, 4);
    c1.sync.strategy = Strategy::Constant;
    c1.variance_every = 0;
    let r1 = run(c1);
    let mut c2 = base(150, 4);
    c2.sync.strategy = Strategy::Constant;
    c2.variance_every = 5;
    let r2 = run(c2);
    assert_eq!(r1.ledger.total_wire_bytes(), r2.ledger.total_wire_bytes());
    assert_eq!(r1.syncs, r2.syncs);
}

#[test]
fn node_counts_scale() {
    for nodes in [1usize, 2, 3, 7, 16] {
        let mut cfg = base(80, nodes);
        cfg.sync.strategy = Strategy::Adaptive;
        let r = run(cfg);
        assert!(r.final_train_loss.is_finite(), "n={nodes}");
        assert_eq!(r.nodes, nodes);
    }
}

#[test]
fn momentum_is_node_local() {
    // With per-node momentum (as the paper specifies), CPSGD p=1 differs
    // from FULLSGD: p=1 averages *parameters after* local momentum
    // steps, FULLSGD averages *gradients before* the momentum step.
    // They must both converge but produce different trajectories.
    let mut c1 = base(100, 4);
    c1.sync.strategy = Strategy::Constant;
    c1.sync.period = 1;
    c1.sync.warmup_iters = 0;
    let r1 = run(c1);
    let mut c2 = base(100, 4);
    c2.sync.strategy = Strategy::Full;
    let r2 = run(c2);
    assert!(r1.final_train_loss.is_finite() && r2.final_train_loss.is_finite());
    assert_ne!(
        r1.final_train_loss, r2.final_train_loss,
        "param-avg p=1 and grad-avg are different algorithms under momentum"
    );
}

#[test]
fn decreasing_matches_cpsgd8_budget_exactly() {
    // paper §V-B: 20-then-5 with switch at half == p=8 budget
    let mut dcfg = base(400, 4);
    dcfg.sync.strategy = Strategy::Decreasing;
    dcfg.sync.dec_first = 20;
    dcfg.sync.dec_second = 5;
    dcfg.sync.warmup_iters = 0;
    let d = run(dcfg);
    let mut ccfg = base(400, 4);
    ccfg.sync.strategy = Strategy::Constant;
    ccfg.sync.period = 8;
    ccfg.sync.warmup_iters = 0;
    let c = run(ccfg);
    assert_eq!(d.syncs, c.syncs, "400/20*? + 200/5 == 400/8");
}

#[test]
fn eval_accuracy_is_probability() {
    let mut cfg = base(100, 2);
    cfg.sync.strategy = Strategy::Adaptive;
    let r = run(cfg);
    let acc = r.recorder.get("eval_acc").unwrap();
    for (_, a) in &acc.points {
        assert!((0.0..=1.0).contains(a), "acc {a} out of range");
    }
}

#[test]
fn lr_schedule_recorded_matches_config() {
    let mut cfg = base(200, 2);
    cfg.sync.strategy = Strategy::Constant;
    let r = run(cfg);
    let lr = r.recorder.get("lr").unwrap();
    let first = lr.points.first().unwrap().1;
    let last = lr.last_y().unwrap();
    assert!((first - 0.1).abs() < 1e-6);
    assert!((last - 0.001).abs() < 1e-6, "after two 0.1x decays: {last}");
}
