//! Integration tests over the HLO/PJRT product path: the coordinator
//! training real AOT artifacts (built by `make artifacts`) end to end,
//! plus runtime/native cross-checks.  All tests skip with a notice if
//! the artifacts directory is missing so `cargo test` works on a fresh
//! checkout before the python build step.

use adpsgd::config::{Backend, ExperimentConfig, LrSchedule};
use adpsgd::experiment::Experiment;
use adpsgd::data::{CharCorpus, DatasetHandle, NodeSource, SynthClass};
use adpsgd::period::Strategy;
use adpsgd::runtime::{EngineFns, HloEngine, Manifest};
use std::sync::Arc;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn hlo_cfg(model: &str, strategy: Strategy, iters: usize, nodes: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("it_{model}_{strategy}");
    cfg.nodes = nodes;
    cfg.iters = iters;
    cfg.eval_every = iters / 2;
    cfg.workload.backend = Backend::Hlo(model.into());
    cfg.workload.eval_batches = 2;
    cfg.optim.lr0 = 0.05;
    cfg.optim.schedule = LrSchedule::Const;
    cfg.sync.strategy = strategy;
    cfg.sync.period = 4;
    cfg.sync.p_init = 2;
    cfg.sync.warmup_iters = 4;
    cfg.sync.ks_frac = 0.2;
    cfg
}

#[test]
fn manifest_lists_models_with_required_fns() {
    let Some(man) = manifest() else { return };
    assert!(man.models.len() >= 3, "expected several model presets");
    for (name, spec) in &man.models {
        assert!(spec.param_count > 0, "{name}");
        assert!(spec.batch > 0, "{name}");
        for f in ["init", "step", "grad", "apply", "eval", "sq_dev"] {
            assert!(spec.files.contains_key(f), "{name} missing {f} artifact");
        }
    }
}

#[test]
fn hlo_engine_roundtrip_small_model() {
    let Some(man) = manifest() else { return };
    let engine = HloEngine::load(&man, "mlp_small", EngineFns::all()).unwrap();
    let spec = man.get("mlp_small").unwrap();
    let n = engine.n_params();
    assert_eq!(n, spec.param_count);

    let dim = *spec.x_shape.last().unwrap();
    let ds = DatasetHandle::Class(Arc::new(SynthClass::new(7, dim, spec.classes, 0.6, 0.0)));
    let mut src = NodeSource::new(ds, 7, 0, spec.batch, 0);
    let batch = src.next_batch();

    let mut w = engine.init(3).unwrap();
    assert!(w.iter().all(|v| v.is_finite()));
    let mut m = vec![0.0f32; n];

    // step decreases loss over repeated batches
    let mut losses = Vec::new();
    for _ in 0..30 {
        let b = src.next_batch();
        losses.push(engine.step(&mut w, &mut m, &b, 0.05).unwrap());
    }
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss should fall: {head} -> {tail}");

    // grad+apply equals step (same batch, same state) — the two HLO
    // entry points must implement the same update rule
    let mut w1 = engine.init(3).unwrap();
    let mut m1 = vec![0.0f32; n];
    let l1 = engine.step(&mut w1, &mut m1, &batch, 0.05).unwrap();
    let mut w2 = engine.init(3).unwrap();
    let mut m2 = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let l2 = engine.grad(&w2, &batch, &mut g).unwrap();
    engine.apply(&mut w2, &mut m2, &g, 0.05).unwrap();
    assert!((l1 - l2).abs() < 1e-5, "losses {l1} vs {l2}");
    let dmax = adpsgd::tensor::max_abs_diff(&w1, &w2);
    assert!(dmax < 1e-5, "step vs grad+apply diverged: {dmax}");

    // sq_dev kernel agrees with the rust hot path
    let hlo = engine.sq_dev(&w1, &w).unwrap();
    let native = adpsgd::tensor::sq_deviation(&w1, &w);
    assert!((hlo - native).abs() <= 1e-4 * (1.0 + native.abs()), "{hlo} vs {native}");
}

#[test]
fn hlo_eval_accuracy_in_range() {
    let Some(man) = manifest() else { return };
    let engine = HloEngine::load(&man, "mlp_small", EngineFns::all()).unwrap();
    let spec = man.get("mlp_small").unwrap();
    let dim = *spec.x_shape.last().unwrap();
    let ds = DatasetHandle::Class(Arc::new(SynthClass::new(9, dim, spec.classes, 0.6, 0.0)));
    let mut src = NodeSource::new(ds, 9, 0, spec.batch, 0);
    let w = engine.init(1).unwrap();
    let (loss, acc) = engine.eval(&w, &src.next_batch()).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn coordinator_trains_hlo_mlp_with_adpsgd() {
    let Some(_man) = manifest() else { return };
    let cfg = hlo_cfg("mlp_small", Strategy::Adaptive, 40, 2);
    let r = Experiment::from_config(cfg).unwrap().run().unwrap();
    assert!(r.final_train_loss.is_finite());
    assert!(r.syncs > 0);
    let loss = r.recorder.get("train_loss").unwrap();
    let first = loss.points.first().unwrap().1;
    let last = loss.last_y().unwrap();
    assert!(last < first, "HLO ADPSGD loss should fall: {first} -> {last}");
}

#[test]
fn coordinator_trains_hlo_transformer_lm() {
    let Some(man) = manifest() else { return };
    if man.get("txf_tiny").is_err() {
        eprintln!("skipping: txf_tiny not in manifest");
        return;
    }
    let cfg = hlo_cfg("txf_tiny", Strategy::Adaptive, 30, 2);
    let r = Experiment::from_config(cfg).unwrap().run().unwrap();
    let loss = r.recorder.get("train_loss").unwrap();
    let first = loss.points.first().unwrap().1;
    let last = loss.last_y().unwrap();
    assert!(last < first, "LM loss should fall: {first} -> {last}");
}

#[test]
fn hlo_fullsgd_matches_qsgd_shape() {
    let Some(_man) = manifest() else { return };
    for strategy in [Strategy::Full, Strategy::Qsgd] {
        let cfg = hlo_cfg("mlp_small", strategy, 20, 2);
        let r = Experiment::from_config(cfg).unwrap().run().unwrap();
        assert!(r.final_train_loss.is_finite(), "{strategy}");
        assert_eq!(r.syncs, 20, "{strategy} syncs every iteration");
    }
}

#[test]
fn char_corpus_batches_are_valid_lm_batches() {
    let corpus = CharCorpus::generate(5, 4096);
    let ds = DatasetHandle::Text(Arc::new(corpus));
    let mut src = NodeSource::new(ds, 5, 1, 4, 16);
    for _ in 0..10 {
        let b = src.next_batch();
        match b {
            adpsgd::data::Batch::Lm { x, y, batch, seq } => {
                assert_eq!(x.len(), batch * seq);
                assert_eq!(y.len(), batch * seq);
                assert!(x.iter().all(|&t| t >= 0));
                assert!(y.iter().all(|&t| t >= 0));
            }
            _ => panic!("expected LM batch"),
        }
    }
}
