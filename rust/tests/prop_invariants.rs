//! Property-based tests on coordinator invariants (routing of sync
//! decisions, batching geometry, state management) plus the numeric
//! substrates, via the `util::prop` micro-framework.

use adpsgd::period::{Adaptive, Constant, Decreasing, PeriodController};
use adpsgd::quant::{decode, encode, QsgdConfig};
use adpsgd::util::prop::{forall, Gen};
use adpsgd::util::rng::Rng;
use adpsgd::{netsim, tensor};

// ------------------------------------------------------------ period control

#[test]
fn prop_constant_controller_exact_budget() {
    forall("constant-budget", 64, |g: &mut Gen| {
        let p = g.usize_in(1..20);
        let iters = g.usize_in(1..2000);
        let mut c = Constant::new(p);
        let syncs = (0..iters).filter(|&k| c.should_sync(k)).count();
        assert_eq!(syncs, iters / p, "p={p} iters={iters}");
    });
}

#[test]
fn prop_gap_between_syncs_equals_current_period() {
    // the controller contract: after on_sync sets period p, the next
    // sync happens exactly p local steps later (Algorithm 2's counter)
    forall("adaptive-gap", 48, |g: &mut Gen| {
        let p_init = g.usize_in(1..6);
        let k_s = g.usize_in(0..50);
        let mut a = Adaptive::new(p_init, 0, k_s, 0.7, 1.3);
        let mut last_sync: Option<usize> = None;
        let lr = 0.1f32;
        for k in 0..600 {
            let p_before = a.current_period();
            if a.should_sync(k) {
                if let Some(prev) = last_sync {
                    assert_eq!(k - prev, p_before, "gap != period at k={k}");
                }
                last_sync = Some(k);
                // random feedback drives the period up and down
                let s_k = g.f32_in(0.0, 0.3) as f64;
                a.on_sync(k, s_k, lr);
            }
        }
    });
}

#[test]
fn prop_adaptive_period_stays_positive_and_bounded() {
    forall("adaptive-bounds", 48, |g: &mut Gen| {
        let mut a = Adaptive::new(g.usize_in(1..8), g.usize_in(0..10), g.usize_in(0..40), 0.7, 1.3);
        let mut syncs = 0usize;
        for k in 0..2000 {
            if a.should_sync(k) {
                syncs += 1;
                a.on_sync(k, g.f32_in(0.0, 1.0) as f64, g.f32_in(1e-4, 1.0));
            }
            let p = a.current_period();
            assert!(p >= 1, "period must stay >= 1");
            assert!(p <= 2 + syncs + a.p_init, "period can grow at most 1 per sync: {p}");
        }
        assert!(syncs >= 1);
    });
}

#[test]
fn prop_decreasing_budget_formula() {
    forall("decreasing-budget", 48, |g: &mut Gen| {
        let first = g.usize_in(1..30);
        let second = g.usize_in(1..30);
        let iters = 2 * g.usize_in(10..500);
        let switch = iters / 2;
        let mut d = Decreasing::new(first, second, switch);
        let syncs = (0..iters).filter(|&k| d.should_sync(k)).count();
        // counter resets only on sync; bound the drift to one period
        let expect = switch / first + (iters - switch) / second;
        let diff = (syncs as i64 - expect as i64).abs();
        assert!(diff <= 1, "first={first} second={second} iters={iters}: {syncs} vs {expect}");
    });
}

// ------------------------------------------------------------------- tensor

#[test]
fn prop_sq_deviation_symmetric_nonneg() {
    forall("sq-dev-sym", 64, |g: &mut Gen| {
        let a = g.vec_normal(1..4096, 2.0);
        let b: Vec<f32> = a.iter().map(|x| x + g.f32_in(-1.0, 1.0)).collect();
        let d1 = tensor::sq_deviation(&a, &b);
        let d2 = tensor::sq_deviation(&b, &a);
        assert!(d1 >= 0.0);
        assert!((d1 - d2).abs() <= 1e-9 * (1.0 + d1), "{d1} vs {d2}");
        assert_eq!(tensor::sq_deviation(&a, &a), 0.0);
    });
}

#[test]
fn prop_momentum_update_linear_in_lr() {
    // with zero momentum state, the update is -lr * g exactly
    forall("momentum-linear", 64, |g: &mut Gen| {
        let w0 = g.vec_normal(1..1024, 1.0);
        let grad: Vec<f32> = w0.iter().map(|_| g.f32_in(-1.0, 1.0)).collect();
        let lr = g.f32_in(1e-4, 0.5);
        let mut w = w0.clone();
        let mut m = vec![0.0f32; w.len()];
        tensor::momentum_update(&mut w, &mut m, &grad, lr, 0.9);
        for i in 0..w.len() {
            let expect = w0[i] - lr * grad[i];
            assert!((w[i] - expect).abs() <= 1e-5 * (1.0 + expect.abs()));
            assert_eq!(m[i], grad[i], "velocity after first step is g");
        }
    });
}

#[test]
fn prop_param_variance_zero_iff_identical() {
    forall("variance-zero", 48, |g: &mut Gen| {
        let n = g.usize_in(1..512);
        let rows_n = g.usize_in(1..8);
        let base = g.vec_normal(n..n + 1, 1.0);
        let rows_data: Vec<Vec<f32>> = (0..rows_n).map(|_| base.clone()).collect();
        let rows: Vec<&[f32]> = rows_data.iter().map(|v| v.as_slice()).collect();
        let mut scratch = vec![0.0f32; n];
        // mean-of-identical-rows rounds in f32, so allow rounding dust
        let var = tensor::param_variance(&rows, &mut scratch);
        let scale = tensor::sq_norm(&base).max(1.0);
        assert!(var <= 1e-12 * scale, "var {var} for identical rows (scale {scale})");
    });
}

// --------------------------------------------------------------------- quant

#[test]
fn prop_qsgd_roundtrip_error_bound() {
    // QSGD guarantee: |x_i - Q(x_i)| <= norm_bucket / levels
    forall("qsgd-error", 48, |g: &mut Gen| {
        let sigma = g.f32_in(0.01, 10.0);
        let x = g.vec_normal(1..4096, sigma);
        let cfg =
            QsgdConfig { levels: [15, 63, 255][g.usize_in(0..3)], bucket: 1 << g.usize_in(4..11) };
        let mut rng = Rng::new(g.seed, 99);
        let enc = encode(&x, &cfg, &mut rng);
        let mut out = vec![0.0f32; x.len()];
        decode(&enc, &mut out);
        for (bi, chunk) in x.chunks(cfg.bucket).enumerate() {
            let norm = enc.norms[bi];
            let tol = norm / cfg.levels as f32 + 1e-6;
            for (j, &xi) in chunk.iter().enumerate() {
                let yi = out[bi * cfg.bucket + j];
                assert!(
                    (xi - yi).abs() <= tol * 1.001,
                    "bucket {bi} elem {j}: |{xi} - {yi}| > {tol}"
                );
                assert_eq!(xi.signum() * yi.signum() >= 0.0, true, "sign flip");
            }
        }
    });
}

#[test]
fn prop_qsgd_unbiased_in_expectation() {
    // stochastic rounding: the mean decode over many seeds approaches x
    forall("qsgd-unbiased", 8, |g: &mut Gen| {
        let n = 256;
        let x = g.vec_normal(n..n + 1, 1.0);
        let cfg = QsgdConfig { levels: 7, bucket: 64 };
        let mut acc = vec![0.0f64; n];
        let trials = 400;
        for t in 0..trials {
            let mut rng = Rng::new(g.seed.wrapping_add(t), 5);
            let enc = encode(&x, &cfg, &mut rng);
            let mut out = vec![0.0f32; n];
            decode(&enc, &mut out);
            for i in 0..n {
                acc[i] += out[i] as f64;
            }
        }
        let norm = (x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()).sqrt();
        let mut worst = 0.0f64;
        for i in 0..n {
            let mean = acc[i] / trials as f64;
            worst = worst.max((mean - x[i] as f64).abs());
        }
        // per-bucket norm ~ sqrt(64); step = norm/7; MC error ~ step/sqrt(trials)*3
        let step = norm / 2.0 / 7.0; // rough per-bucket scale
        assert!(worst < step * 0.35, "bias {worst} vs step {step}");
    });
}

#[test]
fn prop_wire_bytes_shrink_with_levels() {
    forall("qsgd-wire", 32, |g: &mut Gen| {
        let x = g.vec_normal(64..4096, 1.0);
        let mut rng = Rng::new(g.seed, 1);
        let c8 = encode(&x, &QsgdConfig { levels: 255, bucket: 512 }, &mut rng);
        // 8-bit QSGD wire size ~ n bytes + overhead < 4n (f32)
        assert!(c8.wire_bytes() < (x.len() * 4) as u64 / 2, "{}", c8.wire_bytes());
    });
}

// -------------------------------------------------------------------- netsim

#[test]
fn prop_netmodel_monotonicity() {
    forall("netsim-monotone", 64, |g: &mut Gen| {
        let net = netsim::NetModel { bw: g.f32_in(1e8, 1e11) as f64, alpha: g.f32_in(1e-7, 1e-4) as f64 };
        let n = g.usize_in(2..64);
        let b = g.usize_in(1..1 << 24) as u64;
        // time grows with payload
        assert!(net.allreduce_time(n, 2 * b) > net.allreduce_time(n, b));
        // time grows with node count (latency term)
        assert!(net.allreduce_time(n + 1, b) > net.allreduce_time(n, b) - 1e-12);
        // wire bytes below 2x payload (ring optimality)
        assert!(net.allreduce_wire_bytes(n, b) <= 2 * b);
        // PS exchange independent of n
        assert_eq!(net.ps_exchange_time(n, b), net.ps_exchange_time(n + 5, b));
    });
}

// ------------------------------------------------- heterogeneous clusters

use adpsgd::config::ExperimentConfig;
use adpsgd::experiment::Experiment;
use adpsgd::period::Strategy;

/// Train to completion and return the final checkpointed parameter
/// vector as raw bit patterns.
fn final_param_bits(mut cfg: ExperimentConfig, tag: &str) -> Vec<u32> {
    let dir = std::env::temp_dir().join(format!("adpsgd_prop_hetero_{}_{tag}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    cfg.checkpoint_dir = dir.to_string_lossy().into_owned();
    cfg.name = tag.into();
    Experiment::from_config(cfg).unwrap().run().unwrap();
    let snap = adpsgd::checkpoint::Checkpoint::latest(&dir).unwrap().expect("snapshot");
    let ck = adpsgd::checkpoint::Checkpoint::load(&snap).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    ck.w.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn prop_heterogeneity_never_moves_parameters_under_any_collective() {
    // THE cluster-model invariant: random skew, jitter, and fault
    // schedules move modeled clocks only — under both collective
    // algorithms, for every strategy, the trained parameters are
    // bitwise-identical to the homogeneous run of the same seed (and
    // ring == flat, as everywhere else in the tree).
    forall("cluster-bit-identity", 6, |g: &mut Gen| {
        let strategies = [
            Strategy::Constant,
            Strategy::Adaptive,
            Strategy::AdaComm,
            Strategy::PrSgd,
            Strategy::DaSgd,
        ];
        let strat = strategies[g.usize_in(0..strategies.len())];
        let mut base = ExperimentConfig::default();
        base.seed = g.seed;
        base.nodes = g.usize_in(2..4);
        base.iters = 40;
        base.batch_per_node = 8;
        base.eval_every = 0;
        base.variance_every = 0;
        base.checkpoint_every = 20;
        base.workload.input_dim = 16;
        base.workload.hidden = 8;
        base.workload.eval_batches = 1;
        base.optim.momentum = 0.9;
        base.sync.strategy = strat;
        base.sync.period = 4;
        base.sync.p_init = 2;
        base.sync.warmup_iters = 2;
        base.sync.adacomm_tau0 = 4;

        // a random heterogeneous cluster
        let skew = ["linear:2.0", "straggler:3.0"][g.usize_in(0..2)];
        let jitter = g.f32_in(0.0, 0.3) as f64;
        let pauses = g.usize_in(0..3);
        let spikes = g.usize_in(0..3);

        let mut bits: Vec<(String, Vec<u32>)> = Vec::new();
        for algo in [Algo::Flat, Algo::Ring] {
            for hetero in [false, true] {
                let mut cfg = base.clone();
                cfg.sync.collective = algo;
                if hetero {
                    cfg.cluster.skew = skew.into();
                    cfg.cluster.jitter = jitter;
                    cfg.cluster.faults.pauses = pauses;
                    cfg.cluster.faults.pause_secs = 0.05;
                    cfg.cluster.faults.spikes = spikes;
                    cfg.cluster.faults.spike_secs = 2e-3;
                }
                let tag = format!("{strat}_{algo}_{hetero}_{}", g.seed);
                bits.push((tag.clone(), final_param_bits(cfg, &tag)));
            }
        }
        let (ref_tag, ref_bits) = &bits[0];
        for (tag, b) in &bits[1..] {
            assert_eq!(
                b, ref_bits,
                "{tag} diverged from {ref_tag}: skew/faults or the collective moved parameters"
            );
        }
    });
}

// ----------------------------------------------------------------- collective

use adpsgd::collective::{build, Algo, Collective, Poisoned};
use std::sync::Arc;

/// Run one allreduce over `n` rank threads; returns every rank's result.
fn allreduce_all_ranks(comm: &Arc<dyn Collective>, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = inputs.len();
    let results: Vec<std::sync::Mutex<Vec<f32>>> =
        (0..n).map(|_| std::sync::Mutex::new(vec![])).collect();
    std::thread::scope(|scope| {
        for (rank, input) in inputs.iter().enumerate() {
            let comm = Arc::clone(comm);
            let slot = &results[rank];
            scope.spawn(move || {
                let mut buf = input.clone();
                comm.allreduce_mean(rank, &mut buf).unwrap();
                *slot.lock().unwrap() = buf;
            });
        }
    });
    results.into_iter().map(|m| m.into_inner().unwrap()).collect()
}

#[test]
fn prop_allreduce_mean_matches_serial() {
    forall("allreduce-serial", 12, |g: &mut Gen| {
        let n = g.usize_in(2..7);
        let len = g.usize_in(1..2048);
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_normal(len..len + 1, 1.0)).collect();
        // serial reference in the same rank order (and with the same
        // multiply-by-reciprocal rounding) the collectives use
        let inv = 1.0f32 / n as f32;
        let mut expect = vec![0.0f32; len];
        for i in 0..len {
            let mut acc = inputs[0][i];
            for r in 1..n {
                acc += inputs[r][i];
            }
            expect[i] = acc * inv;
        }
        for algo in [Algo::Flat, Algo::Ring] {
            let comm = build(algo, n, len);
            let results = allreduce_all_ranks(&comm, &inputs);
            for (r, got) in results.iter().enumerate() {
                assert_eq!(got, &expect, "{algo}: rank {r} disagrees with serial reference");
            }
        }
    });
}

#[test]
fn prop_ring_and_flat_allreduce_agree() {
    // the two algorithms must produce (bitwise-close, in fact identical)
    // results for random rank counts and buffer lengths — including the
    // n = 1 degenerate case where the collective is a no-op
    forall("ring-flat-agree", 12, |g: &mut Gen| {
        let n = g.usize_in(1..9);
        let len = g.usize_in(1..4097);
        let inputs: Vec<Vec<f32>> =
            (0..n).map(|_| g.vec_normal(len..len + 1, 2.0)).collect();
        let flat = allreduce_all_ranks(&build(Algo::Flat, n, len), &inputs);
        let ring = allreduce_all_ranks(&build(Algo::Ring, n, len), &inputs);
        for r in 0..n {
            let d = adpsgd::tensor::max_abs_diff(&flat[r], &ring[r]);
            assert!(d <= 1e-5, "rank {r}: flat/ring diverged by {d}");
            // stronger: fixed rank-order reduction makes them bit-equal
            assert_eq!(flat[r], ring[r], "rank {r}: expected bit-identical results");
        }
        if n == 1 {
            assert_eq!(flat[0], inputs[0], "n=1 must be a no-op");
        }
    });
}

#[test]
fn prop_ring_and_flat_poison_behavior_identical() {
    forall("ring-flat-poison", 8, |g: &mut Gen| {
        let n = g.usize_in(2..6);
        let len = g.usize_in(1..512);
        for algo in [Algo::Flat, Algo::Ring] {
            let comm = build(algo, n, len);
            assert!(!comm.is_poisoned());
            comm.poison();
            comm.poison(); // idempotent
            assert!(comm.is_poisoned(), "{algo}");
            let mut buf = vec![0.0f32; len];
            assert_eq!(comm.allreduce_mean(0, &mut buf), Err(Poisoned), "{algo}");
            assert_eq!(comm.allreduce_scalar_sum(0, 1.0), Err(Poisoned), "{algo}");
            assert_eq!(comm.broadcast(0, &mut buf), Err(Poisoned), "{algo}");
            assert_eq!(comm.barrier(), Err(Poisoned), "{algo}");
        }
        // n = 1: collectives are no-ops and succeed under both algorithms
        for algo in [Algo::Flat, Algo::Ring] {
            let comm = build(algo, 1, len);
            let mut buf = vec![1.0f32; len];
            assert!(comm.allreduce_mean(0, &mut buf).is_ok(), "{algo}");
            assert_eq!(comm.allreduce_scalar_sum(0, 2.5), Ok(2.5), "{algo}");
        }
    });
}
