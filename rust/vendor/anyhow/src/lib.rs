//! Offline, API-compatible subset of the `anyhow` crate (the registry is
//! not reachable from this build environment, so the crate is vendored
//! as a ~200-line reimplementation of the surface this repo uses):
//!
//! * [`Error`] — a context-carrying boxed error.  `Display` prints the
//!   outermost context; `{:#}` prints the whole `context: ...: cause`
//!   chain, exactly like upstream anyhow.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — format-style construction,
//!   early return, checked condition.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * `Error::is::<E>()` / `Error::downcast_ref::<E>()` — walk the cause
//!   chain (used to distinguish collective-poisoning errors from real
//!   worker failures).
//!
//! Semantics intentionally mirror upstream where this repo depends on
//! them; exotic upstream features (backtraces, dyn chains via
//! `.chain()`) are omitted.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically typed error with a stack of human-readable context
/// strings on top of a root cause.
pub struct Error {
    /// context frames, outermost first
    context: Vec<String>,
    root: Box<dyn StdError + Send + Sync + 'static>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Root cause for errors built from a message (`anyhow!`, `Error::msg`).
struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { context: Vec::new(), root: Box::new(Message(m.to_string())) }
    }

    /// Construct from a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(e: E) -> Error {
        Error { context: Vec::new(), root: Box::new(e) }
    }

    /// Wrap with an additional (outermost) context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.context.insert(0, c.to_string());
        self
    }

    fn chain_start(&self) -> &(dyn StdError + 'static) {
        &*self.root
    }

    /// The lowest-level cause in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur = self.chain_start();
        while let Some(next) = cur.source() {
            cur = next;
        }
        cur
    }

    /// Is some error in the cause chain of type `E`?
    pub fn is<E: StdError + 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }

    /// First error of type `E` in the cause chain, if any.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        let mut cur: Option<&(dyn StdError + 'static)> = Some(self.chain_start());
        while let Some(e) = cur {
            if let Some(hit) = e.downcast_ref::<E>() {
                return Some(hit);
            }
            cur = e.source();
        }
        None
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full "context: context: root: cause" chain
            for c in &self.context {
                write!(f, "{c}: ")?;
            }
            write!(f, "{}", self.root)?;
            let mut src = self.root.source();
            while let Some(s) = src {
                write!(f, ": {s}")?;
                src = s.source();
            }
            Ok(())
        } else if let Some(c) = self.context.first() {
            f.write_str(c)
        } else {
            write!(f, "{}", self.root)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // the full chain; what `unwrap()` panics print
        write!(f, "{:#}", self)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: `{}`",
                ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Leaf;
    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("leaf failure")
        }
    }
    impl StdError for Leaf {}

    #[test]
    fn display_outermost_alternate_full_chain() {
        let e: Error = Error::new(Leaf).context("mid").context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: leaf failure");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let v: i32 = "nope".parse()?;
            Ok(v)
        }
        let e = inner().unwrap_err();
        assert!(e.is::<std::num::ParseIntError>());
    }

    #[test]
    fn downcast_survives_context() {
        let e: Error = Error::new(Leaf).context("while working");
        assert!(e.is::<Leaf>());
        assert_eq!(e.downcast_ref::<Leaf>(), Some(&Leaf));
        assert!(!e.is::<std::io::Error>());
    }

    #[test]
    fn macros_and_option_context() {
        let e = anyhow!("value was {}", 42);
        assert_eq!(e.to_string(), "value was 42");
        fn f() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn ensure_macro() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        fn bare(x: i32) -> Result<()> {
            ensure!(x > 0);
            Ok(())
        }
        assert!(bare(0).unwrap_err().to_string().contains("x > 0"));
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), Leaf> = Err(Leaf);
        let e = r.with_context(|| format!("attempt {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "attempt 3: leaf failure");
    }
}
